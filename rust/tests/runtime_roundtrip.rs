//! Runtime round-trip tests: the HLO/PJRT path must agree with the scalar
//! CPU reference numerics.  This is the cross-layer correctness contract —
//! L1 kernels were verified against the jnp oracle in pytest; here we verify
//! L3's staging (gather/rotate/scatter) + the compiled artifacts against the
//! independent Rust implementation of the same math.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use std::path::Path;

use fasttucker::coordinator::{Algo, Backend, Strategy, TrainConfig, Trainer, Variant};
use fasttucker::cpu_ref;
use fasttucker::model::TuckerModel;
use fasttucker::runtime::Engine;
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::tensor::split::train_test_split;

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: no artifacts/ (run `make artifacts`)");
        None
    }
}

#[test]
fn engine_loads_and_reports() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new(dir).unwrap();
    assert!(engine.manifest().len() >= 50, "expected full artifact set");
    assert_eq!(engine.platform(), "cpu");
    // same name twice -> cached Rc
    let a = engine.load("predict", 3, 16, 16).unwrap();
    let b = engine.load("predict", 3, 16, 16).unwrap();
    assert!(std::rc::Rc::ptr_eq(&a, &b));
}

#[test]
fn predict_artifact_matches_scalar_model() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new(dir).unwrap();
    let exe = engine.load("predict", 3, 16, 16).unwrap();
    let s = exe.info.s;
    let model = TuckerModel::init(&[40, 50, 60], 16, 16, 9);

    // batch of synthetic coordinates
    let coords: Vec<u32> = (0..s)
        .flat_map(|e| [(e % 40) as u32, (e % 50) as u32, (e % 60) as u32])
        .collect();
    let mut a = vec![0f32; 3 * s * 16];
    model.gather_batch(&coords, s, &mut a);
    let mut cores = vec![0f32; 3 * 16 * 16];
    model.pack_cores(&mut cores);
    let out = exe.run(&[&a, &cores]).unwrap();
    for e in (0..s).step_by(17) {
        let want = model.predict_one(&coords[e * 3..e * 3 + 3]);
        let got = out[0][e];
        assert!(
            (want - got).abs() < 1e-3 * (1.0 + want.abs()),
            "entry {e}: scalar {want} vs hlo {got}"
        );
    }
}

#[test]
fn run_rejects_wrong_shapes() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new(dir).unwrap();
    let exe = engine.load("predict", 3, 16, 16).unwrap();
    let bad = vec![0f32; 7];
    assert!(exe.run(&[&bad, &bad]).is_err());
    assert!(exe.run(&[&bad]).is_err());
}

/// HLO epoch == cpu_ref epoch, exactly (to f32 tolerance), on a
/// collision-free tensor.  When every sample touches distinct factor rows,
/// per-sample sequential updates (cpu_ref) and batched block updates (HLO)
/// are mathematically identical, so this pins the whole staging + kernel +
/// scatter pipeline against the independent Rust implementation.
#[test]
fn hlo_epoch_matches_cpu_ref_exactly_without_collisions() {
    let Some(_) = artifacts() else { return };
    // 512 entries (= one artifact block), all coordinates distinct per mode.
    let dim = 600u32;
    let mut t = fasttucker::tensor::SparseTensor::new(vec![dim, dim, dim]);
    let mut rng = fasttucker::util::rng::Pcg32::new(77, 0);
    let mut perms: Vec<Vec<u32>> = (0..3)
        .map(|_| {
            let mut p: Vec<u32> = (0..dim).collect();
            rng.shuffle(&mut p);
            p
        })
        .collect();
    for e in 0..512usize {
        let c = [perms[0][e], perms[1][e], perms[2][e]];
        t.push(&c, rng.gen_normal());
    }
    perms.clear();

    let mut models = Vec::new();
    for backend in [Backend::Hlo, Backend::CpuRef] {
        let mut cfg = TrainConfig::default();
        cfg.backend = backend;
        cfg.seed = 5;
        let mut tr = Trainer::new(&t, cfg).unwrap();
        tr.epoch(&t).unwrap();
        models.push(tr.model.clone());
    }
    let (hlo, cpu) = (&models[0], &models[1]);
    for m in 0..3 {
        for (i, (a, b)) in hlo.factors[m].iter().zip(&cpu.factors[m]).enumerate() {
            assert!(
                (a - b).abs() < 2e-4 * (1.0 + a.abs()),
                "factor[{m}][{i}]: hlo {a} vs cpu {b}"
            );
        }
        for (i, (a, b)) in hlo.cores[m].iter().zip(&cpu.cores[m]).enumerate() {
            assert!(
                (a - b).abs() < 2e-4 * (1.0 + a.abs()),
                "core[{m}][{i}]: hlo {a} vs cpu {b}"
            );
        }
    }
}

/// Every algorithm x variant x strategy combination must run and reduce
/// training error through the HLO path.
#[test]
fn all_algorithms_train_via_hlo() {
    let Some(_) = artifacts() else { return };
    let tensor = generate(&SynthConfig::order_sweep(3, 48, 4_000, 44));
    let (train, test) = train_test_split(&tensor, 0.2, 4);
    for (algo, variant, strategy) in [
        (Algo::Plus, Variant::Tc, Strategy::Calculation),
        (Algo::Plus, Variant::Cc, Strategy::Calculation),
        (Algo::Plus, Variant::Tc, Strategy::Storage),
        (Algo::Plus, Variant::Cc, Strategy::Storage),
        (Algo::FastTucker, Variant::Tc, Strategy::Calculation),
        (Algo::FastTucker, Variant::Cc, Strategy::Calculation),
        (Algo::FasterTucker, Variant::Tc, Strategy::Storage),
        (Algo::FasterTucker, Variant::Cc, Strategy::Storage),
    ] {
        let mut cfg = TrainConfig::default();
        cfg.algo = algo;
        cfg.variant = variant;
        cfg.strategy = strategy;
        let mut tr = Trainer::new(&train, cfg).unwrap();
        let (rmse0, _) = tr.evaluate(&test).unwrap();
        for _ in 0..4 {
            tr.epoch(&train).unwrap();
        }
        let (rmse1, _) = tr.evaluate(&test).unwrap();
        assert!(
            rmse1 < rmse0,
            "{:?}/{:?}/{:?}: rmse {rmse0} -> {rmse1} did not improve",
            algo,
            variant,
            strategy
        );
        assert!(tr.model.param_norm().is_finite());
    }
}

/// Order sweep: the high-order artifact set must be loadable and trainable.
#[test]
fn high_order_hlo_training() {
    let Some(_) = artifacts() else { return };
    for order in [4, 6, 8] {
        let tensor = generate(&SynthConfig::order_sweep(order, 24, 2_000, 5));
        let mut cfg = TrainConfig::default();
        cfg.seed = 6;
        let mut tr = Trainer::new(&tensor, cfg).unwrap();
        let (rmse0, _) = tr.evaluate(&tensor).unwrap();
        for _ in 0..6 {
            tr.epoch(&tensor).unwrap();
        }
        let (rmse1, _) = tr.evaluate(&tensor).unwrap();
        assert!(
            rmse1 < rmse0 * 0.999 && rmse1.is_finite(),
            "order {order}: {rmse0} -> {rmse1}"
        );
    }
}

/// The cpu_ref evaluate and the HLO predict-based evaluate must agree on the
/// same model.
#[test]
fn evaluate_agrees_across_backends() {
    let Some(_) = artifacts() else { return };
    let tensor = generate(&SynthConfig::order_sweep(3, 48, 3_000, 55));
    let (train, test) = train_test_split(&tensor, 0.3, 5);
    let cfg = TrainConfig::default();
    let mut tr = Trainer::new(&train, cfg).unwrap();
    tr.epoch(&train).unwrap();
    let (rmse_hlo, mae_hlo) = tr.evaluate(&test).unwrap();
    let (rmse_cpu, mae_cpu) = cpu_ref::evaluate(&tr.model, &test);
    assert!((rmse_hlo - rmse_cpu).abs() < 1e-3, "{rmse_hlo} vs {rmse_cpu}");
    assert!((mae_hlo - mae_cpu).abs() < 1e-3, "{mae_hlo} vs {mae_cpu}");
}
