//! Shared adversarial corpus for the wire-facing test suites.
//!
//! Both network tiers — the serving front end and the distributed TCP
//! transport — speak newline-delimited JSON frames through
//! `serve::net::frame`, so they share one hostile-input corpus: frames
//! that are not JSON, frames of the wrong shape, binary noise, integers
//! beyond the f64-exact range, an unterminated oversize line, and a
//! connect-and-close.  The invariant every endpoint must hold against
//! all of them: answer a loud error or drop the connection — never
//! panic, never wedge, never corrupt a neighboring frame.
#![allow(dead_code)] // each test crate uses the slice it needs

/// Malformed control frames a hostile peer might open with.  None of
/// them is a valid `join` handshake (the dist coordinator must not
/// spend a member id on any of these) and none is a valid serving
/// request.
pub fn malformed_control_frames() -> Vec<Vec<u8>> {
    let mut frames: Vec<Vec<u8>> = vec![
        // not JSON at all
        b"not json at all\n".to_vec(),
        // valid protocol event, but not a handshake
        b"{\"kind\":\"heartbeat\",\"member\":1}\n".to_vec(),
        // a join that claims an id instead of asking for one
        b"{\"kind\":\"join\",\"member\":42}\n".to_vec(),
        // a join with no member field
        b"{\"kind\":\"join\"}\n".to_vec(),
        // member id beyond 2^53 (not f64-exact)
        b"{\"kind\":\"join\",\"member\":9007199254740994}\n".to_vec(),
        // truncated JSON
        b"{\"kind\":\"join\",\"mem\n".to_vec(),
        // binary noise
        b"\x00\xff\xfe\x01 binary garbage \x80\x81\n".to_vec(),
        // connect and say nothing (immediate close)
        Vec::new(),
    ];
    // an unterminated line twice the 1 MiB control-frame bound: the
    // reader must drop the peer, not buffer forever
    frames.push(vec![b'x'; 2 << 20]);
    frames
}
