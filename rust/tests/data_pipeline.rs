//! Data-pipeline tests: the hardened text parser (round-trip + mutation
//! properties), the frozen on-disk formats (byte-exact golden fixtures for
//! FTB1 / FTB2 / FTCK and a full bit-flip sweep over the FTB2 fixture),
//! the streaming ingester's constant-memory contract, and the acceptance
//! bar of the out-of-core path: a paged FTB2 store trains bit-identically
//! to the same tensor in RAM (block stream, staged slabs, per-epoch RMSE
//! trajectory and final model).

use std::path::{Path, PathBuf};

use fasttucker::coordinator::{tensor_fingerprint, Algo, Backend, TrainConfig, Trainer};
use fasttucker::data::{ingest_file, store, PagedTensor, TensorView};
use fasttucker::model::TuckerModel;
use fasttucker::sampler::{self, BlockIter};
use fasttucker::serve::ModelSnapshot;
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::tensor::{io, SparseTensor};
use fasttucker::util::rng::Pcg32;

// ======================================================================
// helpers
// ======================================================================

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("ft_data_pipeline_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/data")
        .join(name)
}

/// A random tensor with ≥ 1 entry (duplicates allowed — the formats
/// preserve entry order, they do not dedup).
fn random_tensor(rng: &mut Pcg32) -> SparseTensor {
    let order = 2 + rng.gen_index(3);
    let dims: Vec<u32> = (0..order).map(|_| 1 + rng.gen_range(40)).collect();
    let nnz = 1 + rng.gen_index(200);
    let mut t = SparseTensor::new(dims.clone());
    let mut coords = vec![0u32; order];
    for _ in 0..nnz {
        for (c, &d) in coords.iter_mut().zip(&dims) {
            *c = rng.gen_range(d);
        }
        t.push(&coords, rng.gen_normal() * 3.0);
    }
    t
}

fn text_of(t: &SparseTensor) -> String {
    let mut buf = Vec::new();
    io::write_text_to(t, &mut buf).unwrap();
    String::from_utf8(buf).unwrap()
}

/// The byte-exact golden tensor behind `rust/tests/data/golden.*`.
fn golden_tensor() -> SparseTensor {
    let mut t = SparseTensor::new(vec![4, 3, 2]);
    t.push(&[0, 0, 0], 1.5);
    t.push(&[1, 2, 1], -0.25);
    t.push(&[3, 1, 0], 2.0);
    t.push(&[2, 0, 1], 0.75);
    t.push(&[3, 2, 1], -3.5);
    t
}

/// The byte-exact golden model behind `rust/tests/data/golden.ftck`
/// (values chosen exactly representable in f32).
fn golden_model() -> TuckerModel {
    TuckerModel {
        dims: vec![2, 3],
        j: 2,
        r: 2,
        factors: vec![vec![0.5, -1.0, 1.5, 2.0], vec![0.25, -0.75, 1.0, 0.5, -2.0, 3.0]],
        cores: vec![vec![1.0, 0.5, -0.5, 2.0], vec![0.75, -1.5, 2.5, 1.25]],
    }
}

// ======================================================================
// text parser properties
// ======================================================================

#[test]
fn text_roundtrip_property() {
    let mut rng = Pcg32::new(0x7E47, 1);
    for case in 0..120 {
        let t = random_tensor(&mut rng);
        let back = io::parse_text(text_of(&t).as_bytes())
            .unwrap_or_else(|e| panic!("case {case}: {e:#}"));
        assert_eq!(back.dims, t.dims, "case {case}");
        assert_eq!(back.indices, t.indices, "case {case}");
        // shortest-decimal printing makes the value round-trip bit-exact
        let a: Vec<u32> = t.values.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = back.values.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "case {case}");
    }
}

#[test]
fn text_mutations_fail_with_the_offending_line_number() {
    let mut rng = Pcg32::new(0x7E48, 2);
    for case in 0..220 {
        let t = random_tensor(&mut rng);
        let text = text_of(&t);
        let mut lines: Vec<String> = text.lines().map(str::to_string).collect();
        // line 1 is the dims header; entry e sits on line e + 2
        let e = rng.gen_index(t.nnz());
        let lineno = e + 2;
        let entry = lines[lineno - 1].clone();
        let toks: Vec<&str> = entry.split_whitespace().collect();
        lines[lineno - 1] = match rng.gen_index(6) {
            // each arm is guaranteed-invalid: garbage tokens, a dropped
            // value, a trailing token, an out-of-bounds index, a
            // non-finite value, an unparseable value
            0 => "definitely not an entry".to_string(),
            1 => toks[..toks.len() - 1].join(" "),
            2 => format!("{entry} 9"),
            3 => {
                // first index pushed out of bounds
                let mut m = toks.clone();
                let oob = (t.dims[0] + rng.gen_range(5)).to_string();
                m[0] = &oob;
                m.join(" ")
            }
            4 => {
                let mut m = toks.clone();
                m[t.order()] = "nan";
                m.join(" ")
            }
            _ => {
                let mut m = toks.clone();
                m[t.order()] = "1.2.3";
                m.join(" ")
            }
        };
        let mutated = lines.join("\n");
        let err = io::parse_text(mutated.as_bytes())
            .expect_err(&format!("case {case} should fail:\n{mutated}"));
        let msg = format!("{err:#}");
        assert!(
            msg.contains(&format!("line {lineno}")),
            "case {case}: error {msg:?} does not name line {lineno}"
        );
    }
}

#[test]
fn text_garbage_never_panics() {
    let mut rng = Pcg32::new(0x7E49, 3);
    for _ in 0..200 {
        let len = rng.gen_index(200);
        let bytes: Vec<u8> = (0..len)
            .map(|_| b" dims0123456789.#\n-eExz"[rng.gen_index(23)])
            .collect();
        // any outcome is fine as long as it is an Ok/Err, not a panic
        let _ = io::parse_text(&bytes[..]);
    }
}

// ======================================================================
// golden fixtures: the formats are frozen
// ======================================================================

#[test]
fn ftb1_writer_reproduces_the_golden_fixture() {
    let p = tmp("golden_check.ftb");
    io::write_binary(&golden_tensor(), &p).unwrap();
    assert_eq!(
        std::fs::read(&p).unwrap(),
        std::fs::read(fixture("golden.ftb")).unwrap(),
        "FTB1 writer output changed — the format is frozen"
    );
    let back = io::read_binary(&fixture("golden.ftb")).unwrap();
    assert_eq!(back.indices, golden_tensor().indices);
    assert_eq!(back.values, golden_tensor().values);
}

#[test]
fn ftb2_writer_reproduces_the_golden_fixture() {
    let p = tmp("golden_check.ftb2");
    store::write_store(&golden_tensor(), &p, 2).unwrap();
    assert_eq!(
        std::fs::read(&p).unwrap(),
        std::fs::read(fixture("golden.ftb2")).unwrap(),
        "FTB2 writer output changed — the format is frozen"
    );
    let back = store::read_store(&fixture("golden.ftb2")).unwrap();
    assert_eq!(back.indices, golden_tensor().indices);
    assert_eq!(back.values, golden_tensor().values);
}

#[test]
fn ftck_writer_reproduces_the_golden_fixture() {
    let snap = ModelSnapshot::from_model(&golden_model(), Algo::Plus, 7);
    assert_eq!(
        snap.to_bytes(),
        std::fs::read(fixture("golden.ftck")).unwrap(),
        "FTCK serialization changed — the format is frozen"
    );
    let back = ModelSnapshot::load(&fixture("golden.ftck")).unwrap();
    assert_eq!(back.epoch(), 7);
    assert_eq!(back.algo(), Algo::Plus);
    assert_eq!(back.to_model().factors, golden_model().factors);
    assert_eq!(back.to_model().cores, golden_model().cores);
}

#[test]
fn ftb2_bit_flip_sweep_is_always_detected() {
    let good = std::fs::read(fixture("golden.ftb2")).unwrap();
    // sanity: the pristine fixture opens
    PagedTensor::open(&fixture("golden.ftb2")).unwrap();
    let p = tmp("flipped.ftb2");
    for byte in 0..good.len() {
        for bit in 0..8u8 {
            let mut bad = good.clone();
            bad[byte] ^= 1 << bit;
            std::fs::write(&p, &bad).unwrap();
            assert!(
                PagedTensor::open(&p).is_err(),
                "flip of byte {byte} bit {bit} went undetected"
            );
        }
    }
}

// ======================================================================
// ingest: streaming, bounded, exact
// ======================================================================

#[test]
fn ingest_memory_is_bounded_by_the_page_size() {
    let t = generate(&SynthConfig::order_sweep(3, 24, 5_000, 3));
    let text = tmp("bounded.coo");
    io::write_text(&t, &text).unwrap();
    let page = 512;
    let stats = ingest_file(&text, &tmp("bounded.ftb2"), page).unwrap();
    assert_eq!(stats.nnz, t.nnz() as u64);
    assert_eq!(stats.pages, (t.nnz() as u64).div_ceil(page as u64));
    // the constant-memory contract, asserted by construction: the writer
    // never buffered more than one section of entries
    assert!(
        stats.peak_buffered <= page,
        "peak {} exceeds the page size {page}",
        stats.peak_buffered
    );
}

#[test]
fn ingested_ftb1_matches_ingested_text_bitwise() {
    let t = generate(&SynthConfig::order_sweep(4, 16, 3_000, 5));
    let text = tmp("pair.coo");
    let ftb1 = tmp("pair.ftb");
    io::write_text(&t, &text).unwrap();
    io::write_binary(&t, &ftb1).unwrap();
    ingest_file(&text, &tmp("pair_text.ftb2"), 700).unwrap();
    ingest_file(&ftb1, &tmp("pair_ftb1.ftb2"), 700).unwrap();
    let a = std::fs::read(tmp("pair_text.ftb2")).unwrap();
    let b = std::fs::read(tmp("pair_ftb1.ftb2")).unwrap();
    assert_eq!(a, b, "text and FTB1 ingest produced different stores");
    let back = store::read_store(&tmp("pair_text.ftb2")).unwrap();
    assert_eq!(back.indices, t.indices);
    assert_eq!(back.values, t.values);
}

#[test]
fn paged_view_is_indistinguishable_from_ram() {
    let mut rng = Pcg32::new(0xBEEF, 9);
    for case in 0..30 {
        let t = random_tensor(&mut rng);
        let p = tmp(&format!("view_{case}.ftb2"));
        let page = 1 + rng.gen_index(64);
        store::write_store(&t, &p, page).unwrap();
        let paged = PagedTensor::open_with_cache(&p, 2).unwrap();
        assert_eq!(paged.dims(), &t.dims[..]);
        assert_eq!(TensorView::nnz(&paged), t.nnz());
        assert_eq!(paged.mean_value().to_bits(), t.mean_value().to_bits());
        assert_eq!(
            tensor_fingerprint(&paged),
            tensor_fingerprint(&t),
            "case {case}: fingerprints diverge"
        );
        let mut coords = vec![0u32; t.order()];
        for _ in 0..64 {
            let e = rng.gen_index(t.nnz());
            let v = paged.load_entry(e, &mut coords);
            assert_eq!(&coords[..], t.coords(e), "case {case} entry {e}");
            assert_eq!(v.to_bits(), t.values[e].to_bits());
        }
    }
}

// ======================================================================
// out-of-core training parity (the acceptance bar)
// ======================================================================

fn plus_cfg() -> TrainConfig {
    TrainConfig {
        algo: Algo::Plus,
        backend: Backend::CpuRef, // deterministic serial path
        ..TrainConfig::default()
    }
}

#[test]
fn block_stream_and_staged_slabs_are_identical_ram_vs_paged() {
    let t = generate(&SynthConfig::order_sweep(3, 32, 2_000, 11));
    let p = tmp("stream.ftb2");
    store::write_store(&t, &p, 256).unwrap();
    let paged = PagedTensor::open_with_cache(&p, 3).unwrap();
    for epoch in 0..2u64 {
        let mut ram_iter = BlockIter::uniform(&t, 128, 7, epoch);
        let mut paged_iter = BlockIter::uniform(&paged, 128, 7, epoch);
        loop {
            let (a, b) = (ram_iter.next_block(), paged_iter.next_block());
            match (a, b) {
                (None, None) => break,
                (Some(a), Some(b)) => {
                    assert_eq!(a.ids, b.ids, "epoch {epoch}: id schedules diverge");
                    let sa = sampler::stage(&t, &a);
                    let sb = sampler::stage(&paged, &b);
                    assert_eq!(sa.coords, sb.coords, "epoch {epoch}");
                    assert_eq!(sa.lanes, sb.lanes, "epoch {epoch}");
                    assert_eq!(sa.valid, sb.valid, "epoch {epoch}");
                    let va: Vec<u32> = sa.values.iter().map(|v| v.to_bits()).collect();
                    let vb: Vec<u32> = sb.values.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(va, vb, "epoch {epoch}: staged values diverge");
                }
                (a, b) => panic!("epoch {epoch}: stream lengths diverge ({a:?} vs {b:?})"),
            }
        }
    }
}

#[test]
fn training_trajectory_is_bit_identical_ram_vs_paged() {
    // the same bytes reach both paths: the text dump is parsed into RAM
    // on one side and ingested into a store on the other
    let t = generate(&SynthConfig::order_sweep(3, 32, 4_000, 13));
    let text = tmp("parity.coo");
    io::write_text(&t, &text).unwrap();
    let ram = io::read_text(&text).unwrap();
    ingest_file(&text, &tmp("parity.ftb2"), 1024).unwrap();
    let paged = PagedTensor::open(&tmp("parity.ftb2")).unwrap();

    let mut a = Trainer::new(&ram, plus_cfg()).unwrap();
    let mut b = Trainer::new(&paged, plus_cfg()).unwrap();
    for epoch in 0..4 {
        a.epoch(&ram).unwrap();
        b.epoch(&paged).unwrap();
        // evaluate both models against the same in-RAM tensor: the RMSE
        // trajectories must agree to the last bit
        let (rmse_a, mae_a) = a.evaluate(&ram).unwrap();
        let (rmse_b, mae_b) = b.evaluate(&ram).unwrap();
        assert_eq!(
            rmse_a.to_bits(),
            rmse_b.to_bits(),
            "epoch {epoch}: RMSE diverged ({rmse_a} vs {rmse_b})"
        );
        assert_eq!(mae_a.to_bits(), mae_b.to_bits(), "epoch {epoch}");
    }
    assert_eq!(a.model.factors, b.model.factors, "final factors diverged");
    assert_eq!(a.model.cores, b.model.cores, "final cores diverged");
}

#[test]
fn paged_training_rejects_index_hungry_algorithms() {
    let t = golden_tensor();
    let p = tmp("needs_plus.ftb2");
    store::write_store(&t, &p, 2).unwrap();
    let paged = PagedTensor::open(&p).unwrap();
    for algo in [Algo::FastTucker, Algo::FasterTucker, Algo::FasterTuckerCoo] {
        let cfg = TrainConfig { algo, ..plus_cfg() };
        let err = Trainer::new(&paged, cfg).expect_err("index algos need RAM");
        assert!(format!("{err:#}").contains("plus"), "unhelpful error: {err:#}");
    }
}
