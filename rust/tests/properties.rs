//! Property-based tests (proptest is not in the offline crate set, so this
//! file carries a tiny seeded-sweep harness: N random cases per property,
//! failures report the case seed for replay).
//!
//! Properties cover the coordinator-facing invariants: sampling coverage and
//! constraints (Table 3), gather/scatter consistency, index correctness,
//! split semantics, cost-model monotonicity, JSON round-trips.

use fasttucker::cost;
use fasttucker::model::TuckerModel;
use fasttucker::sampler::{self, PAD, WARP_M};
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::tensor::{split, FiberIndex, ModeSliceIndex, SparseTensor};
use fasttucker::util::json::Json;
use fasttucker::util::rng::Pcg32;

/// Run `prop` for `cases` random seeds; panic with the failing seed.
fn forall(cases: u64, prop: impl Fn(&mut Pcg32)) {
    for seed in 0..cases {
        let mut rng = Pcg32::new(0xBEEF ^ seed, seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            panic!("property failed for case seed {seed}: {e:?}");
        }
    }
}

fn random_tensor(rng: &mut Pcg32) -> SparseTensor {
    let order = 3 + rng.gen_index(3);
    let dim = 8 + rng.gen_range(56) as u32;
    let nnz = 100 + rng.gen_index(2000);
    generate(&SynthConfig::order_sweep(order, dim, nnz, rng.next_u64()))
}

#[test]
fn prop_uniform_blocks_partition_omega() {
    forall(8, |rng| {
        let t = random_tensor(rng);
        let s = [64usize, 128, 256][rng.gen_index(3)];
        let blocks = sampler::uniform_blocks(&t, s, rng.next_u64(), rng.next_u64());
        let mut seen = vec![false; t.nnz()];
        for b in &blocks {
            assert_eq!(b.ids.len(), s);
            for &id in b.ids.iter().filter(|&&i| i != PAD) {
                assert!(!seen[id as usize], "duplicate sample");
                seen[id as usize] = true;
            }
        }
        assert!(seen.iter().all(|&x| x), "missing samples");
    });
}

#[test]
fn prop_mode_slice_blocks_warp_constraint() {
    forall(6, |rng| {
        let t = random_tensor(rng);
        let mode = rng.gen_index(t.order());
        let idx = ModeSliceIndex::build(&t, mode);
        let blocks = sampler::mode_slice_blocks(&idx, 128, rng.next_u64(), 0);
        let mut count = 0;
        for b in &blocks {
            for warp in b.ids.chunks(WARP_M) {
                let vals: Vec<u32> = warp
                    .iter()
                    .filter(|&&i| i != PAD)
                    .map(|&i| t.coords(i as usize)[mode])
                    .collect();
                count += vals.len();
                assert!(vals.windows(2).all(|w| w[0] == w[1]), "mixed slice in warp");
            }
        }
        assert_eq!(count, t.nnz());
    });
}

#[test]
fn prop_fiber_index_partitions_and_groups() {
    forall(6, |rng| {
        let t = random_tensor(rng);
        let mode = rng.gen_index(t.order());
        let idx = FiberIndex::build(&t, mode);
        let mut seen = vec![false; t.nnz()];
        for f in 0..idx.num_fibers() {
            let ids = idx.fiber(f);
            let key = |e: u32| {
                let c = t.coords(e as usize);
                c.iter()
                    .enumerate()
                    .filter(|(m, _)| *m != mode)
                    .map(|(_, &v)| v)
                    .collect::<Vec<_>>()
            };
            let k0 = key(ids[0]);
            for &e in ids {
                assert_eq!(key(e), k0);
                assert!(!seen[e as usize]);
                seen[e as usize] = true;
            }
        }
        assert!(seen.iter().all(|&x| x));
    });
}

#[test]
fn prop_streaming_scheduler_matches_eager() {
    forall(8, |rng| {
        let t = random_tensor(rng);
        let s = [64usize, 128, 256][rng.gen_index(3)];
        let seed = rng.next_u64();
        let epoch = rng.next_u64();
        let eager = sampler::uniform_blocks(&t, s, seed, epoch);
        let lazy = sampler::BlockIter::uniform(&t, s, seed, epoch).collect_blocks();
        assert_eq!(eager.len(), lazy.len());
        for (a, b) in eager.iter().zip(&lazy) {
            assert_eq!(a.ids, b.ids);
            assert_eq!(a.valid, b.valid);
        }
        // grouped strategies too: warp-aligned packing must be identical
        let mode = rng.gen_index(t.order());
        let idx = ModeSliceIndex::build(&t, mode);
        let eager = sampler::mode_slice_blocks(&idx, s, seed, epoch);
        let lazy = sampler::BlockIter::mode_slice(&idx, s, seed, epoch).collect_blocks();
        assert_eq!(eager.len(), lazy.len());
        for (a, b) in eager.iter().zip(&lazy) {
            assert_eq!(a.ids, b.ids);
        }
    });
}

#[test]
fn prop_gather_scatter_identity() {
    forall(8, |rng| {
        let order = 3 + rng.gen_index(3);
        let dims: Vec<u32> = (0..order).map(|_| 8 + rng.gen_range(40)).collect();
        let model = TuckerModel::init(&dims, 16, 16, rng.next_u64());
        let mut m2 = model.clone();
        let s = 32;
        let valid = 1 + rng.gen_index(s);
        let coords: Vec<u32> = (0..valid * order)
            .map(|i| rng.gen_range(dims[i % order]))
            .collect();
        let mut buf = vec![0f32; order * s * 16];
        model.gather_batch(&coords, valid, &mut buf);
        // scatter the gathered rows straight back: model must be unchanged
        // unless the batch contained duplicate rows (last-wins is identity
        // here because values are identical).
        m2.scatter_batch(&coords, valid, &buf);
        for m in 0..order {
            assert_eq!(model.factors[m], m2.factors[m], "mode {m} changed");
        }
    });
}

#[test]
fn prop_rotated_gather_matches_plain() {
    forall(8, |rng| {
        let order = 3 + rng.gen_index(2);
        let dims: Vec<u32> = (0..order).map(|_| 8 + rng.gen_range(24)).collect();
        let model = TuckerModel::init(&dims, 16, 16, rng.next_u64());
        let s = 16;
        let valid = s;
        let coords: Vec<u32> = (0..valid * order)
            .map(|i| rng.gen_range(dims[i % order]))
            .collect();
        let mut plain = vec![0f32; order * s * 16];
        model.gather_batch(&coords, valid, &mut plain);
        for mode in 0..order {
            let mut rot = vec![0f32; order * s * 16];
            model.gather_batch_rotated(&coords, valid, mode, &mut rot);
            for k in 0..order {
                let src = (mode + k) % order;
                assert_eq!(
                    &rot[k * s * 16..(k + 1) * s * 16],
                    &plain[src * s * 16..(src + 1) * s * 16],
                    "mode {mode} pos {k}"
                );
            }
        }
    });
}

#[test]
fn prop_split_partition_disjoint_union() {
    forall(8, |rng| {
        let t = random_tensor(rng);
        let frac = 0.1 + rng.gen_f64() * 0.4;
        let (tr, te) = split::train_test_split(&t, frac, rng.next_u64());
        assert_eq!(tr.nnz() + te.nnz(), t.nnz());
        // re-splitting with the same seed is identical
        let seed = 777;
        let (a1, _) = split::train_test_split(&t, frac, seed);
        let (a2, _) = split::train_test_split(&t, frac, seed);
        assert_eq!(a1.indices, a2.indices);
    });
}

#[test]
fn prop_cost_model_monotone() {
    forall(16, |rng| {
        let s = cost::Shape {
            n: 3 + rng.gen_index(6),
            j: 16 * (1 + rng.gen_index(2)),
            r: 16 * (1 + rng.gen_index(2)),
            m: 16,
        };
        // Table 4's central ordering must hold for every shape
        let plus = cost::params_read(cost::Algo::FastTuckerPlus, s);
        let faster = cost::params_read(cost::Algo::FasterTucker, s);
        let fast = cost::params_read(cost::Algo::FastTucker, s);
        assert!(plus <= faster && faster <= fast, "{s:?}");
        // cost grows with every dimension of the shape
        let bigger = cost::Shape { n: s.n + 1, ..s };
        assert!(cost::params_read(cost::Algo::FastTuckerPlus, bigger) > plus);
        assert!(
            cost::d_chain_muls(cost::Algo::FastTuckerPlus, bigger)
                > cost::d_chain_muls(cost::Algo::FastTuckerPlus, s)
        );
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    forall(32, |rng| {
        // build a random JSON value, dump, parse, compare
        fn gen_value(rng: &mut Pcg32, depth: usize) -> Json {
            match if depth == 0 { rng.gen_index(4) } else { rng.gen_index(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.gen_f32() < 0.5),
                2 => Json::Num((rng.gen_f64() * 2000.0 - 1000.0).round()),
                3 => Json::Str(format!("s{}-\"q\"\n", rng.next_u32())),
                4 => Json::Arr((0..rng.gen_index(4)).map(|_| gen_value(rng, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..rng.gen_index(4))
                        .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        let v = gen_value(rng, 3);
        let re = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, re);
    });
}

#[test]
fn prop_sort_dedup_idempotent_and_sorted() {
    forall(8, |rng| {
        let mut t = SparseTensor::new(vec![16, 16, 16]);
        for _ in 0..rng.gen_index(500) {
            t.push(
                &[rng.gen_range(16), rng.gen_range(16), rng.gen_range(16)],
                rng.gen_normal(),
            );
        }
        t.sort_dedup();
        let once = (t.indices.clone(), t.values.clone());
        t.sort_dedup();
        assert_eq!((t.indices.clone(), t.values.clone()), once);
        for e in 1..t.nnz() {
            assert!(t.coords(e - 1) < t.coords(e), "not strictly sorted");
        }
    });
}
