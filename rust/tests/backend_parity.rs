//! Cross-backend and scheduler-parity tests for the refactored execution
//! stack: streaming scheduler vs eager block lists, `ParallelCpu` vs
//! `CpuRef` trajectories, multi-threaded convergence, and the tensor
//! fingerprint guard.  All CPU-only — no artifacts required.

use fasttucker::coordinator::{tensor_fingerprint, Algo, Backend, TrainConfig, Trainer};
use fasttucker::sampler::{self, BlockIter};
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::tensor::split::train_test_split;
use fasttucker::tensor::{FiberIndex, ModeSliceIndex, SparseTensor};

fn assert_blocks_eq(eager: &[sampler::Block], lazy: &[sampler::Block], what: &str) {
    assert_eq!(eager.len(), lazy.len(), "{what}: block count");
    for (i, (a, b)) in eager.iter().zip(lazy).enumerate() {
        assert_eq!(a.ids, b.ids, "{what}: block {i} ids");
        assert_eq!(a.valid, b.valid, "{what}: block {i} valid");
    }
}

/// The streaming scheduler and the eager samplers must agree exactly for a
/// fixed seed, for every strategy.
#[test]
fn streaming_scheduler_matches_eager_blocks() {
    let t = generate(&SynthConfig::order_sweep(3, 40, 2_500, 31));
    for (s, seed, epoch) in [(128usize, 1u64, 0u64), (256, 9, 3)] {
        assert_blocks_eq(
            &sampler::uniform_blocks(&t, s, seed, epoch),
            &BlockIter::uniform(&t, s, seed, epoch).collect_blocks(),
            "uniform",
        );
        for mode in 0..t.order() {
            let sidx = ModeSliceIndex::build(&t, mode);
            assert_blocks_eq(
                &sampler::mode_slice_blocks(&sidx, s, seed, epoch),
                &BlockIter::mode_slice(&sidx, s, seed, epoch).collect_blocks(),
                "mode_slice",
            );
            let fidx = FiberIndex::build(&t, mode);
            assert_blocks_eq(
                &sampler::fiber_blocks(&fidx, s, seed, epoch),
                &BlockIter::fiber(&fidx, s, seed, epoch).collect_blocks(),
                "fiber",
            );
            assert_blocks_eq(
                &sampler::fiber_blocks_coo(&fidx, s, seed, epoch),
                &BlockIter::fiber_coo(&fidx, s, seed, epoch).collect_blocks(),
                "fiber_coo",
            );
        }
    }
}

/// `ParallelCpu` with one worker runs the identical scalar code path as
/// `CpuRef`, so RMSE trajectories must match to f32 tolerance.
#[test]
fn parallel_cpu_one_thread_matches_cpu_ref() {
    let tensor = generate(&SynthConfig::order_sweep(3, 32, 4_000, 17));
    let (train, test) = train_test_split(&tensor, 0.2, 17);
    for algo in [Algo::Plus, Algo::FastTucker, Algo::FasterTucker] {
        let mut curves: Vec<Vec<f64>> = Vec::new();
        for backend in [Backend::CpuRef, Backend::ParallelCpu] {
            let mut cfg = TrainConfig::default();
            cfg.backend = backend;
            cfg.algo = algo;
            cfg.threads = 1;
            let mut tr = Trainer::new(&train, cfg).unwrap();
            let mut curve = Vec::new();
            for _ in 0..4 {
                tr.epoch(&train).unwrap();
                let (rmse, _) = tr.evaluate(&test).unwrap();
                curve.push(rmse);
            }
            curves.push(curve);
        }
        for (a, b) in curves[0].iter().zip(&curves[1]) {
            assert!(
                (a - b).abs() < 1e-5 * (1.0 + a.abs()),
                "{algo:?}: cpu_ref {a} vs parallel_cpu(1) {b}"
            );
        }
    }
}

/// The paper's Hogwild claim, reproduced: the parallel backend with ≥2
/// workers converges on the quickstart synthetic tensor.
#[test]
fn parallel_cpu_multithreaded_converges() {
    let tensor = generate(&SynthConfig::netflix_like(30_000, 7));
    let (train, test) = train_test_split(&tensor, 0.2, 7);
    let mut cfg = TrainConfig::default();
    cfg.backend = Backend::ParallelCpu;
    cfg.threads = 4;
    let mut tr = Trainer::new(&train, cfg).unwrap();
    assert!(tr.platform().contains("parallel_cpu"));
    let (rmse0, _) = tr.evaluate(&test).unwrap();
    let mut last = rmse0;
    for _ in 0..10 {
        tr.epoch(&train).unwrap();
        let (rmse, _) = tr.evaluate(&test).unwrap();
        last = rmse;
    }
    assert!(
        last < rmse0 * 0.9 && last.is_finite(),
        "no convergence under Hogwild: {rmse0} -> {last}"
    );
    assert!(tr.model.param_norm().is_finite());
}

/// Every algorithm must also converge through the parallel backend (the
/// per-mode schedules shard blocks too).
#[test]
fn all_algorithms_converge_parallel_cpu() {
    let tensor = generate(&SynthConfig::order_sweep(3, 32, 3_000, 9));
    let (train, test) = train_test_split(&tensor, 0.2, 9);
    for algo in [Algo::Plus, Algo::FastTucker, Algo::FasterTucker, Algo::FasterTuckerCoo] {
        let mut cfg = TrainConfig::default();
        cfg.backend = Backend::ParallelCpu;
        cfg.threads = 3;
        cfg.algo = algo;
        let mut tr = Trainer::new(&train, cfg).unwrap();
        let (rmse0, _) = tr.evaluate(&test).unwrap();
        for _ in 0..8 {
            tr.epoch(&train).unwrap();
        }
        let (rmse1, _) = tr.evaluate(&test).unwrap();
        assert!(rmse1 < rmse0, "{algo:?}: {rmse0} -> {rmse1}");
    }
}

/// The fingerprint guard must reject a *different* tensor even when the
/// dims and nnz match exactly (the old nnz-only check accepted this).
#[test]
fn fingerprint_rejects_same_size_tensor() {
    // identical dims and nnz, different values — the old nnz-only check
    // could not tell these apart
    let mut a = SparseTensor::new(vec![16, 16, 16]);
    let mut b = SparseTensor::new(vec![16, 16, 16]);
    for e in 0..200u32 {
        let c = [e % 16, (e / 3) % 16, (e / 7) % 16];
        a.push(&c, 1.0 + (e % 5) as f32);
        b.push(&c, 5.0 - (e % 5) as f32);
    }
    a.sort_dedup();
    b.sort_dedup();
    assert_eq!(a.nnz(), b.nnz());
    assert_eq!(a.dims, b.dims);
    assert_ne!(tensor_fingerprint(&a), tensor_fingerprint(&b));

    let mut cfg = TrainConfig::default();
    cfg.backend = Backend::CpuRef;
    let mut tr = Trainer::new(&a, cfg).unwrap();
    assert!(tr.epoch(&b).is_err(), "same-size impostor accepted");
    assert!(tr.epoch(&a).is_ok());
}

/// Fingerprint sanity: stable for the same tensor, sensitive to a value
/// edit at either end.
#[test]
fn fingerprint_is_stable_and_sensitive() {
    let t = generate(&SynthConfig::order_sweep(3, 24, 500, 5));
    assert_eq!(tensor_fingerprint(&t), tensor_fingerprint(&t.clone()));
    let mut edited = t.clone();
    let last = edited.nnz() - 1;
    edited.values[last] += 1.0;
    assert_ne!(tensor_fingerprint(&t), tensor_fingerprint(&edited));
}

/// Regression: the guard still rejects a different-nnz tensor (the old
/// behavior) through the public API.
#[test]
fn fingerprint_rejects_different_nnz() {
    let a = generate(&SynthConfig::order_sweep(3, 32, 1_000, 1));
    let b = generate(&SynthConfig::order_sweep(3, 32, 2_000, 1));
    let mut cfg = TrainConfig::default();
    cfg.backend = Backend::ParallelCpu;
    cfg.threads = 2;
    let mut tr = Trainer::new(&a, cfg).unwrap();
    assert!(tr.epoch(&b).is_err());
}

/// Staged blocks must carry full `[S, N]` coordinate slabs with defined
/// padding (satellite of the scheduler refactor).
#[test]
fn staged_blocks_have_full_defined_slabs() {
    let t: SparseTensor = generate(&SynthConfig::order_sweep(4, 16, 700, 3));
    let n = t.order();
    let mut it = BlockIter::uniform(&t, 64, 2, 1);
    let mut blocks = 0;
    while let Some(b) = it.next_block() {
        let staged = sampler::stage(&t, &b);
        assert_eq!(staged.coords.len(), 64 * n);
        assert_eq!(staged.values.len(), 64);
        for e in staged.valid..64 {
            assert!(staged.coords[e * n..(e + 1) * n].iter().all(|&c| c == 0));
        }
        blocks += 1;
    }
    assert!(blocks > 0);
}
