//! Parity of the tiled CPU microkernels (`fasttucker::kernel`) against the
//! scalar oracle (`cpu_ref::step::*_scalar`), across all three algorithms
//! and both phases, including ragged (non-tile-multiple, offset) ranges
//! and both invariant policies.  The tiled kernels are written to perform
//! the same operations in the same order as the oracle, so the 1e-5
//! tolerance required here is expected to hold exactly.
//!
//! The SIMD tier (`KernelPolicy::Simd`) re-associates reductions and fuses
//! multiply-adds, so it is pinned *tolerance-bounded* against the same
//! oracle (per-step relative bounds; a looser compounding bound on whole
//! training trajectories), while the exact tiers (`Tiled`/`Scalar`) are
//! additionally pinned **bit-identical** to each other end-to-end.

use fasttucker::coordinator::{Algo, Backend, TrainConfig, Trainer};
use fasttucker::cpu_ref::step::BlockData;
use fasttucker::cpu_ref::{self, step, Hyper};
use fasttucker::kernel::{self, InvariantPolicy, KernelCfg, KernelPolicy};
use fasttucker::model::{SharedFactors, TuckerModel};
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::tensor::{FiberIndex, SparseTensor};

const TOL: f32 = 1e-5;

/// Stage a whole tensor as one block: entry-major coords, mode-major lanes,
/// values — in `order` order.
fn staged(t: &SparseTensor, order: &[u32]) -> (Vec<u32>, Vec<u32>, Vec<f32>) {
    let n = t.order();
    let s = order.len();
    let mut coords = vec![0u32; s * n];
    let mut values = vec![0f32; s];
    for (slot, &e) in order.iter().enumerate() {
        coords[slot * n..(slot + 1) * n].copy_from_slice(t.coords(e as usize));
        values[slot] = t.values[e as usize];
    }
    let mut lanes = vec![0u32; n * s];
    for m in 0..n {
        for e in 0..s {
            lanes[m * s + e] = coords[e * n + m];
        }
    }
    (coords, lanes, values)
}

fn assert_close(a: &[f32], b: &[f32], what: &str) {
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() < TOL, "{what}[{i}]: tiled {x} vs scalar {y}");
    }
}

struct Setup {
    tensor: SparseTensor,
    model: TuckerModel,
    hyper: Hyper,
}

fn setup(j: usize, r: usize, seed: u64) -> Setup {
    // 3 modes, dims small enough that factor-row collisions occur within a
    // block — the case that forces sequential tile semantics.
    let tensor = generate(&SynthConfig::order_sweep(3, 24, 900, seed));
    let model = TuckerModel::init(&tensor.dims, j, r, seed ^ 0x5EED);
    Setup {
        tensor,
        model,
        hyper: Hyper::default(),
    }
}

/// Ragged ranges: a full range plus an offset sub-range that is not a
/// multiple of the 16-slot tile.
fn ranges(nnz: usize) -> Vec<std::ops::Range<usize>> {
    vec![0..nnz, 3..nnz - 5, 0..7]
}

fn tiled_cfg(invariant: InvariantPolicy) -> KernelCfg {
    KernelCfg {
        policy: KernelPolicy::Tiled,
        invariant,
    }
}

fn simd_cfg(invariant: InvariantPolicy) -> KernelCfg {
    KernelCfg {
        policy: KernelPolicy::Simd,
        invariant,
    }
}

/// Relative-tolerance comparison for the SIMD tier (reductions
/// re-associate, FMA fusion re-rounds — exact equality is not expected).
fn assert_close_rel(a: &[f32], b: &[f32], tol: f32, what: &str) {
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + y.abs()),
            "{what}[{i}]: simd {x} vs scalar {y}"
        );
    }
}

#[test]
fn plus_factor_parity() {
    for (j, r) in [(16, 16), (32, 16), (16, 32)] {
        let s = setup(j, r, 3);
        let ids: Vec<u32> = (0..s.tensor.nnz() as u32).collect();
        let (coords, lanes, values) = staged(&s.tensor, &ids);
        for range in ranges(s.tensor.nnz()) {
            let mut a = s.model.clone();
            let mut b = s.model.clone();
            let cores = s.model.cores.clone();
            let data = BlockData {
                cores: &cores,
                c_store: &[],
                coords: &coords,
                lanes: &lanes,
                values: &values,
                n: 3,
                j,
                r,
                hyper: s.hyper,
            };
            {
                let shared = SharedFactors::new(&mut a.factors, j);
                let cfg = tiled_cfg(InvariantPolicy::Recompute);
                kernel::plus_factor_range(&shared, &data, range.clone(), cfg);
            }
            {
                let shared = SharedFactors::new(&mut b.factors, j);
                step::plus_factor_scalar(&shared, &data, range.clone());
            }
            for m in 0..3 {
                assert_close(&a.factors[m], &b.factors[m], "plus factors");
            }
        }
    }
}

#[test]
fn plus_core_parity() {
    let (j, r) = (16, 16);
    let s = setup(j, r, 5);
    let ids: Vec<u32> = (0..s.tensor.nnz() as u32).collect();
    let (coords, lanes, values) = staged(&s.tensor, &ids);
    for range in ranges(s.tensor.nnz()) {
        let mut a = s.model.clone();
        let mut b = s.model.clone();
        let cores = s.model.cores.clone();
        let data = BlockData {
            cores: &cores,
            c_store: &[],
            coords: &coords,
            lanes: &lanes,
            values: &values,
            n: 3,
            j,
            r,
            hyper: s.hyper,
        };
        let mut ga = vec![0f32; 3 * j * r];
        let mut gb = vec![0f32; 3 * j * r];
        {
            let shared = SharedFactors::new(&mut a.factors, j);
            let cfg = tiled_cfg(InvariantPolicy::Recompute);
            kernel::plus_core_range(&shared, &data, range.clone(), &mut ga, cfg);
        }
        {
            let shared = SharedFactors::new(&mut b.factors, j);
            step::plus_core_scalar(&shared, &data, range.clone(), &mut gb);
        }
        assert_close(&ga, &gb, "plus core grad");
    }
}

#[test]
fn fasttucker_parity_both_phases() {
    let (j, r) = (16, 16);
    let s = setup(j, r, 7);
    let ids: Vec<u32> = (0..s.tensor.nnz() as u32).collect();
    let (coords, lanes, values) = staged(&s.tensor, &ids);
    for mode in 0..3 {
        for range in ranges(s.tensor.nnz()) {
            let mut a = s.model.clone();
            let mut b = s.model.clone();
            let cores = s.model.cores.clone();
            let data = BlockData {
                cores: &cores,
                c_store: &[],
                coords: &coords,
                lanes: &lanes,
                values: &values,
                n: 3,
                j,
                r,
                hyper: s.hyper,
            };
            let mut ga = vec![0f32; j * r];
            let mut gb = vec![0f32; j * r];
            {
                let shared = SharedFactors::new(&mut a.factors, j);
                let cfg = tiled_cfg(InvariantPolicy::Recompute);
                kernel::mode_factor_range(&shared, &data, mode, range.clone(), cfg);
                kernel::mode_core_range(&shared, &data, mode, range.clone(), &mut ga, cfg);
            }
            {
                let shared = SharedFactors::new(&mut b.factors, j);
                step::mode_factor_scalar(&shared, &data, mode, range.clone());
                step::mode_core_scalar(&shared, &data, mode, range.clone(), &mut gb);
            }
            assert_close(&a.factors[mode], &b.factors[mode], "fasttucker factors");
            assert_close(&ga, &gb, "fasttucker core grad");
        }
    }
}

/// FasterTucker parity, with the block staged in *fiber order* so the
/// per-fiber invariant cache actually gets hits, under both policies.
#[test]
fn fastertucker_parity_both_policies() {
    let (j, r) = (16, 16);
    let s = setup(j, r, 9);
    let mode = 1usize;
    let fibers = FiberIndex::build(&s.tensor, mode);
    let order: Vec<u32> = (0..fibers.num_fibers())
        .flat_map(|f| fibers.fiber(f).to_vec())
        .collect();
    let (coords, lanes, values) = staged(&s.tensor, &order);
    let c_store: Vec<Vec<f32>> = (0..3)
        .map(|m| cpu_ref::compute_c_full(&s.model, m))
        .collect();
    for invariant in [InvariantPolicy::Recompute, InvariantPolicy::CachePerFiber] {
        for range in ranges(order.len()) {
            let mut a = s.model.clone();
            let mut b = s.model.clone();
            let cores = s.model.cores.clone();
            let data = BlockData {
                cores: &cores,
                c_store: &c_store,
                coords: &coords,
                lanes: &lanes,
                values: &values,
                n: 3,
                j,
                r,
                hyper: s.hyper,
            };
            let mut ga = vec![0f32; j * r];
            let mut gb = vec![0f32; j * r];
            {
                let shared = SharedFactors::new(&mut a.factors, j);
                let cfg = tiled_cfg(invariant);
                kernel::stored_factor_range(&shared, &data, mode, range.clone(), cfg);
                kernel::stored_core_range(&shared, &data, mode, range.clone(), &mut ga, cfg);
            }
            {
                let shared = SharedFactors::new(&mut b.factors, j);
                step::stored_factor_scalar(&shared, &data, mode, range.clone());
                step::stored_core_scalar(&shared, &data, mode, range.clone(), &mut gb);
            }
            assert_close(&a.factors[mode], &b.factors[mode], "fastertucker factors");
            assert_close(&ga, &gb, "fastertucker core grad");
        }
    }
}

/// SIMD step parity against the scalar oracle over every monomorphized
/// `(J, R)` shape, both phases, including ragged/offset ranges.
#[test]
fn simd_plus_parity_all_monomorphized_shapes() {
    for (j, r) in [(16, 16), (16, 32), (32, 16), (32, 32), (48, 48), (64, 64)] {
        let s = setup(j, r, 11);
        let ids: Vec<u32> = (0..s.tensor.nnz() as u32).collect();
        let (coords, lanes, values) = staged(&s.tensor, &ids);
        for range in ranges(s.tensor.nnz()) {
            let mut a = s.model.clone();
            let mut b = s.model.clone();
            let cores = s.model.cores.clone();
            let data = BlockData {
                cores: &cores,
                c_store: &[],
                coords: &coords,
                lanes: &lanes,
                values: &values,
                n: 3,
                j,
                r,
                hyper: s.hyper,
            };
            let mut ga = vec![0f32; 3 * j * r];
            let mut gb = vec![0f32; 3 * j * r];
            {
                let shared = SharedFactors::new(&mut a.factors, j);
                let cfg = simd_cfg(InvariantPolicy::Recompute);
                kernel::plus_factor_range(&shared, &data, range.clone(), cfg);
                kernel::plus_core_range(&shared, &data, range.clone(), &mut ga, cfg);
            }
            {
                let shared = SharedFactors::new(&mut b.factors, j);
                step::plus_factor_scalar(&shared, &data, range.clone());
                step::plus_core_scalar(&shared, &data, range.clone(), &mut gb);
            }
            for m in 0..3 {
                let what = format!("simd plus factors ({j},{r})");
                assert_close_rel(&a.factors[m], &b.factors[m], 2e-5, &what);
            }
            assert_close_rel(&ga, &gb, 2e-5, &format!("simd plus core grad ({j},{r})"));
        }
    }
}

/// SIMD parity for the storage-scheme (FasterTucker) kernels under both
/// invariant policies — the fiber-ordered path where the exclusion cache
/// (kept bit-exact even under SIMD) interacts with SIMD dots and updates.
#[test]
fn simd_fastertucker_parity_both_policies() {
    let (j, r) = (16, 16);
    let s = setup(j, r, 13);
    let mode = 1usize;
    let fibers = FiberIndex::build(&s.tensor, mode);
    let order: Vec<u32> = (0..fibers.num_fibers())
        .flat_map(|f| fibers.fiber(f).to_vec())
        .collect();
    let (coords, lanes, values) = staged(&s.tensor, &order);
    let c_store: Vec<Vec<f32>> = (0..3)
        .map(|m| cpu_ref::compute_c_full(&s.model, m))
        .collect();
    for invariant in [InvariantPolicy::Recompute, InvariantPolicy::CachePerFiber] {
        for range in ranges(order.len()) {
            let mut a = s.model.clone();
            let mut b = s.model.clone();
            let cores = s.model.cores.clone();
            let data = BlockData {
                cores: &cores,
                c_store: &c_store,
                coords: &coords,
                lanes: &lanes,
                values: &values,
                n: 3,
                j,
                r,
                hyper: s.hyper,
            };
            let mut ga = vec![0f32; j * r];
            let mut gb = vec![0f32; j * r];
            {
                let shared = SharedFactors::new(&mut a.factors, j);
                let cfg = simd_cfg(invariant);
                kernel::stored_factor_range(&shared, &data, mode, range.clone(), cfg);
                kernel::stored_core_range(&shared, &data, mode, range.clone(), &mut ga, cfg);
            }
            {
                let shared = SharedFactors::new(&mut b.factors, j);
                step::stored_factor_scalar(&shared, &data, mode, range.clone());
                step::stored_core_scalar(&shared, &data, mode, range.clone(), &mut gb);
            }
            assert_close_rel(&a.factors[mode], &b.factors[mode], 2e-5, "simd ft factors");
            assert_close_rel(&ga, &gb, 2e-5, "simd ft core grad");
        }
    }
}

/// End-to-end: a CpuRef trainer with tiled kernels must reproduce the
/// scalar trainer's RMSE trajectory for every algorithm.
#[test]
fn trainer_trajectories_match_across_kernel_policies() {
    let tensor = generate(&SynthConfig::order_sweep(3, 32, 3_000, 21));
    let (train, test) = fasttucker::tensor::split::train_test_split(&tensor, 0.2, 1);
    for algo in [Algo::Plus, Algo::FastTucker, Algo::FasterTucker, Algo::FasterTuckerCoo] {
        let mut curves: Vec<Vec<f64>> = Vec::new();
        for policy in [KernelPolicy::Tiled, KernelPolicy::Scalar] {
            let mut cfg = TrainConfig::default();
            cfg.backend = Backend::CpuRef;
            cfg.algo = algo;
            cfg.cpu_kernel = policy;
            let mut tr = Trainer::new(&train, cfg).unwrap();
            let mut curve = Vec::new();
            for _ in 0..3 {
                tr.epoch(&train).unwrap();
                let (rmse, _) = tr.evaluate(&test).unwrap();
                curve.push(rmse);
            }
            curves.push(curve);
        }
        for (a, b) in curves[0].iter().zip(&curves[1]) {
            assert!(
                (a - b).abs() < 1e-5 * (1.0 + a.abs()),
                "{algo:?}: tiled {a} vs scalar {b}"
            );
        }
    }
}

/// Exact-mode regression: the `Tiled` and `Scalar` trajectories must stay
/// **bit-identical** — down to every factor entry — proving the SIMD
/// refactor did not perturb either exact tier.
#[test]
fn exact_policies_stay_bit_identical() {
    let tensor = generate(&SynthConfig::order_sweep(3, 32, 2_000, 23));
    let (train, test) = fasttucker::tensor::split::train_test_split(&tensor, 0.2, 1);
    for algo in [Algo::Plus, Algo::FasterTucker] {
        let mut runs: Vec<(TuckerModel, Vec<f64>)> = Vec::new();
        for policy in [KernelPolicy::Tiled, KernelPolicy::Scalar] {
            let cfg = TrainConfig {
                backend: Backend::CpuRef,
                algo,
                cpu_kernel: policy,
                ..TrainConfig::default()
            };
            let mut tr = Trainer::new(&train, cfg).unwrap();
            let mut curve = Vec::new();
            for _ in 0..2 {
                tr.epoch(&train).unwrap();
                let (rmse, _) = tr.evaluate(&test).unwrap();
                curve.push(rmse);
            }
            runs.push((tr.model.clone(), curve));
        }
        let (tiled_model, tiled_curve) = &runs[0];
        let (scalar_model, scalar_curve) = &runs[1];
        assert_eq!(tiled_curve, scalar_curve, "{algo:?}: rmse curves diverged");
        for m in 0..3 {
            assert_eq!(
                tiled_model.factors[m], scalar_model.factors[m],
                "{algo:?}: factor {m} not bit-identical"
            );
        }
        assert_eq!(tiled_model.cores, scalar_model.cores, "{algo:?}: cores");
    }
}

/// End-to-end SIMD trajectory: per-step rounding differences compound over
/// epochs, so the whole-trajectory bound is looser than the per-step one
/// (documented tracking bound, not a drift allowance — SGD on this problem
/// is contractive enough that 1e-3 relative holds with slack).
#[test]
fn simd_trainer_trajectory_tracks_exact() {
    let tensor = generate(&SynthConfig::order_sweep(3, 32, 3_000, 25));
    let (train, test) = fasttucker::tensor::split::train_test_split(&tensor, 0.2, 1);
    for algo in [Algo::Plus, Algo::FasterTucker] {
        let mut curves: Vec<Vec<f64>> = Vec::new();
        for policy in [KernelPolicy::Scalar, KernelPolicy::Simd] {
            let cfg = TrainConfig {
                backend: Backend::CpuRef,
                algo,
                cpu_kernel: policy,
                ..TrainConfig::default()
            };
            let mut tr = Trainer::new(&train, cfg).unwrap();
            let mut curve = Vec::new();
            for _ in 0..3 {
                tr.epoch(&train).unwrap();
                let (rmse, _) = tr.evaluate(&test).unwrap();
                curve.push(rmse);
            }
            curves.push(curve);
        }
        for (a, b) in curves[0].iter().zip(&curves[1]) {
            assert!(
                (a - b).abs() < 1e-2 * (1.0 + a.abs()),
                "{algo:?}: scalar {a} vs simd {b}"
            );
        }
    }
}
