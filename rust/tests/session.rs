//! Session-layer tests: the RunSpec JSON round-trip (property-style over
//! random specs), the typed validation rejection table (every `SpecError`
//! variant triggered), early stopping on a plateaued run, and exact
//! parity between a scheduled `Session` run and the hand-rolled trainer
//! loop it replaced.

use std::path::PathBuf;

use fasttucker::coordinator::{Algo, Backend, Strategy, TrainConfig, Trainer, Variant};
use fasttucker::cpu_ref::Hyper;
use fasttucker::kernel::KernelPolicy;
use fasttucker::session::{
    DataSource, EarlyStop, NullObserver, Recorder, RunSpec, Schedule, Session, SpecError,
    SynthPreset, SynthSpec,
};
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::tensor::split::train_test_split;
use fasttucker::util::json::Json;
use fasttucker::util::rng::Pcg32;

// ======================================================================
// helpers
// ======================================================================

fn store_dir() -> PathBuf {
    let dir = std::env::temp_dir().join("ft_session_store_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A small but real FTB2 store on disk (validation opens the header).
/// Tests run in parallel, so each caller names its own file.
fn valid_store(name: &str) -> PathBuf {
    let path = store_dir().join(name);
    let tensor = fasttucker::tensor::io::toy_dataset();
    fasttucker::data::store::write_store(&tensor, &path, 16).unwrap();
    path
}

/// A spec that passes validation from a clean checkout: toy data, CPU
/// backend, default schedule.
fn valid_spec() -> RunSpec {
    RunSpec {
        data: DataSource::Toy,
        train: TrainConfig {
            backend: Backend::ParallelCpu,
            ..TrainConfig::default()
        },
        schedule: Schedule::default(),
        metrics: None,
    }
}

fn random_u64(rng: &mut Pcg32) -> u64 {
    rng.next_u64()
}

/// A random but finite hyper-parameter value (small rational).
fn random_hyper(rng: &mut Pcg32) -> f32 {
    (rng.gen_range(10_000) as f32) / 997.0
}

fn random_spec(rng: &mut Pcg32) -> RunSpec {
    let data = match rng.gen_range(4) {
        0 => DataSource::Toy,
        1 => DataSource::File(PathBuf::from(format!(
            "/tmp/tensor_{}.ftb",
            rng.gen_range(1000)
        ))),
        2 => DataSource::Store(PathBuf::from(format!(
            "/tmp/store_{}.ftb2",
            rng.gen_range(1000)
        ))),
        _ => DataSource::Synth(SynthSpec {
            preset: [SynthPreset::Netflix, SynthPreset::Yahoo, SynthPreset::Order]
                [rng.gen_index(3)],
            order: 3 + rng.gen_index(5),
            dim: 8 + rng.gen_range(1000),
            nnz: rng.gen_index(1 << 20),
            // exercise the > 2^53 string fallback in roughly half the draws
            seed: if rng.gen_range(2) == 0 {
                random_u64(rng)
            } else {
                rng.gen_range(1 << 20) as u64
            },
        }),
    };
    let train = TrainConfig {
        algo: [
            Algo::FastTucker,
            Algo::FasterTucker,
            Algo::FasterTuckerCoo,
            Algo::Plus,
        ][rng.gen_index(4)],
        variant: [Variant::Tc, Variant::Cc][rng.gen_index(2)],
        strategy: [Strategy::Calculation, Strategy::Storage][rng.gen_index(2)],
        backend: [Backend::Hlo, Backend::CpuRef, Backend::ParallelCpu][rng.gen_index(3)],
        // round-tripping must work for *any* value, valid or not
        j: rng.gen_index(100),
        r: rng.gen_index(100),
        hyper: Hyper {
            lr_a: random_hyper(rng),
            lr_b: random_hyper(rng),
            lam_a: random_hyper(rng),
            lam_b: random_hyper(rng),
        },
        seed: random_u64(rng),
        artifact_dir: PathBuf::from(format!("artifacts_{}", rng.gen_range(100))),
        threads: rng.gen_index(64),
        workers: rng.gen_index(8),
        cpu_kernel: [KernelPolicy::Tiled, KernelPolicy::Scalar, KernelPolicy::Simd]
            [rng.gen_index(3)],
    };
    let schedule = Schedule {
        epochs: rng.gen_index(1000),
        eval_every: rng.gen_index(10),
        test_frac: (rng.gen_range(1000) as f64) / 1000.0,
        early_stop: if rng.gen_range(2) == 0 {
            None
        } else {
            Some(EarlyStop {
                patience: rng.gen_index(10),
                min_delta: (rng.gen_range(1000) as f64) / 1e6,
            })
        },
        lr_decay: if rng.gen_range(2) == 0 {
            None
        } else {
            Some((1 + rng.gen_range(1000)) as f32 / 1000.0)
        },
        checkpoint_every: rng.gen_index(10),
        checkpoint: if rng.gen_range(2) == 0 {
            None
        } else {
            Some(PathBuf::from(format!("/tmp/ckpt_{}.ftc", rng.gen_range(1000))))
        },
        publish_every: rng.gen_index(10),
    };
    RunSpec {
        data,
        train,
        schedule,
        metrics: if rng.gen_range(2) == 0 {
            None
        } else {
            Some(PathBuf::from(format!(
                "/tmp/metrics_{}.jsonl",
                rng.gen_range(1000)
            )))
        },
    }
}

// ======================================================================
// JSON round-trip
// ======================================================================

#[test]
fn spec_json_roundtrip_property() {
    let mut rng = Pcg32::new(0x5EC5, 0x11);
    for i in 0..300 {
        let spec = random_spec(&mut rng);
        let text = spec.dump();
        let back = RunSpec::parse_str(&text)
            .unwrap_or_else(|e| panic!("case {i}: parse failed: {e}\nspec: {text}"));
        assert_eq!(back, spec, "case {i} did not round-trip: {text}");
    }
}

#[test]
fn spec_default_roundtrips_and_validates() {
    let spec = RunSpec::default();
    assert_eq!(RunSpec::parse_str(&spec.dump()).unwrap(), spec);
    // default = toy data + auto backend: valid from a clean checkout AND
    // from a checkout with artifacts
    spec.validate().unwrap();
}

#[test]
fn spec_file_roundtrip() {
    let dir = std::env::temp_dir().join("ft_session_spec_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.spec.json");
    let spec = valid_spec();
    spec.save(&path).unwrap();
    assert_eq!(RunSpec::load(&path).unwrap(), spec);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn spec_parse_rejects_garbage() {
    assert!(RunSpec::parse_str("").is_err());
    assert!(RunSpec::parse_str("{}").is_err());
    assert!(RunSpec::parse_str(r#"{"version":99}"#).is_err());
    // a valid envelope with a bad enum value
    let mut spec_text = valid_spec().dump();
    spec_text = spec_text.replace("\"plus\"", "\"nonsense\"");
    assert!(RunSpec::parse_str(&spec_text).is_err());
}

// ======================================================================
// validation rejection table
// ======================================================================

#[test]
fn validate_accepts_the_base_spec() {
    valid_spec().validate().unwrap();
}

type Mutation = Box<dyn Fn(&mut RunSpec)>;
type Expectation = fn(&SpecError) -> bool;

#[test]
fn validate_rejection_table() {
    // each row mutates the valid base spec to trigger exactly one variant
    let cases: Vec<(&str, Mutation, Expectation)> = vec![
        (
            "j not multiple of 16",
            Box::new(|s| s.train.j = 8),
            |e| matches!(e, SpecError::JNotTileable { j: 8 }),
        ),
        (
            "j zero",
            Box::new(|s| s.train.j = 0),
            |e| matches!(e, SpecError::JNotTileable { j: 0 }),
        ),
        (
            "r not multiple of 16",
            Box::new(|s| s.train.r = 24),
            |e| matches!(e, SpecError::RNotTileable { r: 24 }),
        ),
        (
            "threads on serial backend",
            Box::new(|s| {
                s.train.backend = Backend::CpuRef;
                s.train.threads = 4;
            }),
            |e| {
                matches!(
                    e,
                    SpecError::ThreadsOnSerialBackend {
                        backend: Backend::CpuRef,
                        threads: 4
                    }
                )
            },
        ),
        (
            "hlo without artifacts",
            Box::new(|s| {
                s.train.backend = Backend::Hlo;
                s.train.artifact_dir = PathBuf::from("/nonexistent/ft_artifacts");
            }),
            |e| matches!(e, SpecError::HloWithoutArtifacts { .. }),
        ),
        (
            "missing data file",
            Box::new(|s| s.data = DataSource::File(PathBuf::from("/nonexistent/t.ftb"))),
            |e| matches!(e, SpecError::MissingData { .. }),
        ),
        (
            "empty synth",
            Box::new(|s| {
                s.data = DataSource::Synth(SynthSpec {
                    nnz: 0,
                    ..SynthSpec::default()
                })
            }),
            |e| matches!(e, SpecError::EmptySynth),
        ),
        (
            "non-finite hyper",
            Box::new(|s| s.train.hyper.lr_b = f32::NAN),
            |e| matches!(e, SpecError::NonFiniteHyper { name: "lr_b" }),
        ),
        (
            "zero epochs",
            Box::new(|s| s.schedule.epochs = 0),
            |e| matches!(e, SpecError::ZeroEpochs),
        ),
        (
            "bad test frac",
            Box::new(|s| s.schedule.test_frac = 1.5),
            |e| matches!(e, SpecError::BadTestFrac { .. }),
        ),
        (
            "eval without split",
            Box::new(|s| s.schedule.test_frac = 0.0),
            |e| matches!(e, SpecError::EvalWithoutSplit),
        ),
        (
            "early stop without eval",
            Box::new(|s| {
                s.schedule.eval_every = 0;
                s.schedule.test_frac = 0.0;
                s.schedule.early_stop = Some(EarlyStop::default());
            }),
            |e| matches!(e, SpecError::EarlyStopWithoutEval),
        ),
        (
            "early stop zero patience",
            Box::new(|s| {
                s.schedule.early_stop = Some(EarlyStop {
                    patience: 0,
                    min_delta: 1e-4,
                })
            }),
            |e| matches!(e, SpecError::BadEarlyStop { patience: 0, .. }),
        ),
        (
            "bad lr decay",
            Box::new(|s| s.schedule.lr_decay = Some(0.0)),
            |e| matches!(e, SpecError::BadLrDecay { .. }),
        ),
        (
            "checkpoint cadence without path",
            Box::new(|s| s.schedule.checkpoint_every = 2),
            |e| matches!(e, SpecError::CheckpointCadenceWithoutPath),
        ),
        (
            "missing store",
            Box::new(|s| {
                s.data = DataSource::Store(PathBuf::from("/nonexistent/t.ftb2"));
            }),
            |e| matches!(e, SpecError::MissingData { .. }),
        ),
        (
            "store that is not an FTB2 file",
            Box::new(|s| {
                let p = store_dir().join("not_a_store.ftb2");
                std::fs::write(&p, b"dims 2 2\n0 0 1.0\n").unwrap();
                s.data = DataSource::Store(p);
            }),
            |e| matches!(e, SpecError::StoreInvalid { .. }),
        ),
        (
            "store with a non-plus algorithm",
            Box::new(|s| {
                s.data = DataSource::Store(valid_store("needs_plus.ftb2"));
                s.train.algo = Algo::FastTucker;
                s.schedule.test_frac = 0.0;
                s.schedule.eval_every = 0;
            }),
            |e| matches!(e, SpecError::StoreNeedsPlus { .. }),
        ),
        (
            "store with a held-out split",
            Box::new(|s| s.data = DataSource::Store(valid_store("with_split.ftb2"))),
            |e| matches!(e, SpecError::StoreWithSplit),
        ),
        (
            "workers on the hlo backend",
            Box::new(|s| {
                s.train.workers = 2;
                s.train.backend = Backend::Hlo;
            }),
            |e| matches!(e, SpecError::WorkersOnHlo { workers: 2 }),
        ),
        (
            "workers with a non-plus algorithm",
            Box::new(|s| {
                s.train.workers = 2;
                s.train.algo = Algo::FastTucker;
            }),
            |e| {
                matches!(
                    e,
                    SpecError::WorkersNeedPlus {
                        algo: Algo::FastTucker
                    }
                )
            },
        ),
        (
            "workers with a publish cadence",
            Box::new(|s| {
                s.train.workers = 2;
                s.schedule.publish_every = 3;
            }),
            |e| matches!(e, SpecError::WorkersWithPublish),
        ),
        (
            "metrics path in a nonexistent directory",
            Box::new(|s| {
                s.metrics = Some(PathBuf::from("/nonexistent/ft_metrics/m.jsonl"));
            }),
            |e| matches!(e, SpecError::BadMetricsPath { .. }),
        ),
        (
            "metrics path is a directory",
            Box::new(|s| s.metrics = Some(std::env::temp_dir())),
            |e| matches!(e, SpecError::BadMetricsPath { .. }),
        ),
    ];
    for (label, mutate, expect) in cases {
        let mut spec = valid_spec();
        mutate(&mut spec);
        let err = spec
            .validate()
            .expect_err(&format!("case {label:?} should fail validation"));
        assert!(
            expect(&err),
            "case {label:?} produced the wrong variant: {err:?}"
        );
        // every error formats to something human-readable
        assert!(!err.to_string().is_empty());
    }
}

// ======================================================================
// session runs
// ======================================================================

fn small_tensor() -> fasttucker::tensor::SparseTensor {
    generate(&SynthConfig::order_sweep(3, 32, 3_000, 9))
}

fn cpu_cfg() -> TrainConfig {
    TrainConfig {
        backend: Backend::CpuRef,
        ..TrainConfig::default()
    }
}

#[test]
fn early_stopping_on_plateau() {
    // zero learning rates => the model never changes => RMSE is constant
    // from epoch 1 on, so the plateau policy must cut the run short
    let cfg = TrainConfig {
        hyper: Hyper {
            lr_a: 0.0,
            lr_b: 0.0,
            ..Hyper::default()
        },
        ..cpu_cfg()
    };
    let schedule = Schedule {
        epochs: 30,
        eval_every: 1,
        test_frac: 0.25,
        early_stop: Some(EarlyStop {
            patience: 2,
            min_delta: 0.0,
        }),
        ..Schedule::default()
    };
    let mut session = Session::with_tensor(&small_tensor(), cfg, schedule).unwrap();
    let mut rec = Recorder::default();
    let report = session.run(&mut rec).unwrap();
    assert!(report.stopped_early, "plateaued run must stop early");
    assert_eq!(report.epochs_run, 2, "patience 2 => exactly 2 strikes");
    assert!(report.epochs_run < 30);
    // recorder saw init eval + one event per epoch
    assert_eq!(rec.events.len(), report.epochs_run + 1);
    assert_eq!(rec.events[0].epoch, 0);
    assert!(rec.report.is_some());
}

#[test]
fn improving_run_does_not_stop_early() {
    let schedule = Schedule {
        epochs: 4,
        eval_every: 1,
        test_frac: 0.25,
        early_stop: Some(EarlyStop {
            patience: 3,
            min_delta: 0.0,
        }),
        ..Schedule::default()
    };
    let mut session = Session::with_tensor(&small_tensor(), cpu_cfg(), schedule).unwrap();
    let report = session.run(&mut NullObserver).unwrap();
    assert_eq!(report.epochs_run, 4);
    assert!(!report.stopped_early);
    // SGD on the planted low-rank signal must actually improve
    let init = report.history[0].rmse.unwrap();
    assert!(report.best_rmse.unwrap() < init);
}

#[test]
fn session_matches_manual_trainer_loop_exactly() {
    // the acceptance bar for the session layer: the scheduled run is
    // bit-identical to the hand-rolled loop it replaced
    let tensor = small_tensor();
    let cfg = cpu_cfg();
    let epochs = 3;

    let schedule = Schedule {
        epochs,
        eval_every: 1,
        test_frac: 0.2,
        ..Schedule::default()
    };
    let mut session = Session::with_tensor(&tensor, cfg.clone(), schedule).unwrap();
    let report = session.run(&mut NullObserver).unwrap();

    let (train, test) = train_test_split(&tensor, 0.2, cfg.seed);
    let mut trainer = Trainer::new(&train, cfg).unwrap();
    let mut manual_rmse = f64::NAN;
    let mut manual_mae = f64::NAN;
    for _ in 1..=epochs {
        trainer.epoch(&train).unwrap();
        let (rmse, mae) = trainer.evaluate(&test).unwrap();
        manual_rmse = rmse;
        manual_mae = mae;
    }
    assert_eq!(report.final_rmse, Some(manual_rmse));
    assert_eq!(report.final_mae, Some(manual_mae));
    assert_eq!(report.epochs_run, epochs);
}

#[test]
fn session_writes_scheduled_checkpoints() {
    let dir = std::env::temp_dir().join("ft_session_ckpt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.ftc");
    let schedule = Schedule {
        epochs: 3,
        eval_every: 0,
        test_frac: 0.0,
        checkpoint_every: 2,
        checkpoint: Some(path.clone()),
        ..Schedule::default()
    };
    let mut session = Session::with_tensor(&small_tensor(), cpu_cfg(), schedule).unwrap();
    let mut rec = Recorder::default();
    session.run(&mut rec).unwrap();
    // cadence fired at epoch 2, final checkpoint written after epoch 3
    assert!(rec.events.iter().any(|e| e.epoch == 2 && e.checkpoint.is_some()));
    let snap = fasttucker::serve::ModelSnapshot::load(&path).unwrap();
    assert_eq!(snap.epoch(), 3);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lr_decay_reaches_the_kernels() {
    // with decay d over e epochs the trainer's live rate is lr * d^e, and
    // the recorded per-epoch rates are the ones in effect before decay
    let decay = 0.5f32;
    let schedule = Schedule {
        epochs: 3,
        eval_every: 0,
        test_frac: 0.0,
        lr_decay: Some(decay),
        ..Schedule::default()
    };
    let cfg = cpu_cfg();
    let lr0 = cfg.hyper.lr_a;
    let mut session = Session::with_tensor(&small_tensor(), cfg, schedule).unwrap();
    let mut rec = Recorder::default();
    session.run(&mut rec).unwrap();
    let rates: Vec<f32> = rec.events.iter().map(|e| e.lr_a).collect();
    assert_eq!(rates, vec![lr0, lr0 * decay, lr0 * decay * decay]);
    assert_eq!(
        session.trainer().cfg.hyper.lr_a,
        lr0 * decay * decay * decay
    );
}

#[test]
fn from_spec_runs_toy_end_to_end() {
    let spec = RunSpec {
        schedule: Schedule {
            epochs: 2,
            ..Schedule::default()
        },
        ..valid_spec()
    };
    let mut session = Session::from_spec(&spec).unwrap();
    let report = session.run(&mut NullObserver).unwrap();
    assert_eq!(report.epochs_run, 2);
    assert!(report.final_rmse.unwrap().is_finite());
}

/// The passivity contract, pinned: the same spec with and without a
/// metrics sink yields a bit-identical model and per-epoch RMSE/MAE
/// history, and the sink itself is well-formed JSONL with per-epoch
/// train counters.
#[test]
fn metrics_are_passive_and_the_jsonl_is_well_formed() {
    let dir = std::env::temp_dir().join("ft_session_metrics_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("metrics.jsonl");

    // the deterministic serial reference backend: passivity here means
    // bit-identical, not merely statistically equal
    let base = RunSpec {
        train: TrainConfig {
            backend: Backend::CpuRef,
            ..TrainConfig::default()
        },
        schedule: Schedule {
            epochs: 2,
            ..Schedule::default()
        },
        ..valid_spec()
    };
    let mut plain = Session::from_spec(&base).unwrap();
    let plain_report = plain.run(&mut NullObserver).unwrap();

    let observed_spec = RunSpec {
        metrics: Some(path.clone()),
        ..base.clone()
    };
    observed_spec.validate().unwrap();
    let mut observed = Session::from_spec(&observed_spec).unwrap();
    let observed_report = observed.run(&mut NullObserver).unwrap();

    // the trajectory is bit-identical: every evaluated epoch, to the bit
    assert_eq!(plain_report.epochs_run, observed_report.epochs_run);
    let history_bits: Vec<_> = plain_report
        .history
        .iter()
        .map(|e| (e.epoch, e.rmse.map(f64::to_bits), e.mae.map(f64::to_bits)))
        .collect();
    let observed_bits: Vec<_> = observed_report
        .history
        .iter()
        .map(|e| (e.epoch, e.rmse.map(f64::to_bits), e.mae.map(f64::to_bits)))
        .collect();
    assert_eq!(history_bits, observed_bits);

    // ... and so is the saved FTM1 model, byte for byte
    let (pa, pb) = (dir.join("plain.ftm"), dir.join("observed.ftm"));
    plain.trainer().model.save(&pa).unwrap();
    observed.trainer().model.save(&pb).unwrap();
    assert_eq!(std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());

    // the sink: one "metrics" line per epoch plus the final snapshot,
    // each parsing and carrying the train counters
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<Json> = text
        .lines()
        .map(|l| Json::parse(l).expect("line parses"))
        .collect();
    let scopes: Vec<&str> = lines
        .iter()
        .map(|l| l.get("scope").and_then(|s| s.as_str()).unwrap())
        .collect();
    assert_eq!(scopes, vec!["epoch", "epoch", "final"]);
    for l in &lines {
        assert_eq!(l.get("kind").and_then(|k| k.as_str()), Some("metrics"));
        let epochs = l
            .get("counters")
            .and_then(|c| c.get("train.epochs"))
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!(epochs >= 1.0);
        let hist_count = l
            .get("hists")
            .and_then(|h| h.get("train.epoch_ns"))
            .and_then(|h| h.get("count"))
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!(hist_count >= 1.0);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn from_spec_rejects_invalid() {
    let mut spec = valid_spec();
    spec.train.j = 12;
    assert!(Session::from_spec(&spec).is_err());
}

#[test]
fn from_spec_trains_out_of_core_from_a_store() {
    // a Store source must stay paged (train_tensor() is None) and still
    // drive the schedule end to end
    let spec = RunSpec {
        data: DataSource::Store(valid_store("run_from_spec.ftb2")),
        schedule: Schedule {
            epochs: 2,
            eval_every: 0,
            test_frac: 0.0,
            ..Schedule::default()
        },
        ..valid_spec()
    };
    spec.validate().unwrap();
    let mut session = Session::from_spec(&spec).unwrap();
    assert!(session.train_tensor().is_none(), "store runs must stay paged");
    let tensor = fasttucker::tensor::io::toy_dataset();
    assert_eq!(session.train_nnz(), tensor.nnz());
    assert_eq!(session.train_dims(), &tensor.dims[..]);
    let report = session.run(&mut NullObserver).unwrap();
    assert_eq!(report.epochs_run, 2);
    assert!(report.final_rmse.is_none(), "no split => no evaluation");
}
