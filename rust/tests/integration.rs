//! End-to-end integration tests across the whole L3 stack (tensor substrate
//! -> samplers -> trainer -> checkpointing), independent of the artifact
//! directory where possible (cpu_ref backend), so they run even before
//! `make artifacts`.

use std::path::Path;

use fasttucker::coordinator::{Algo, Backend, TrainConfig, Trainer};
use fasttucker::model::TuckerModel;
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::tensor::{io, split::train_test_split};

#[test]
fn toy_dataset_end_to_end_cpu() {
    let t = io::toy_dataset();
    let mut cfg = TrainConfig::default();
    cfg.backend = Backend::CpuRef;
    cfg.hyper.lr_a = 0.05;
    cfg.hyper.lr_b = 0.02;
    let mut tr = Trainer::new(&t, cfg).unwrap();
    let (rmse0, _) = tr.evaluate(&t).unwrap();
    for _ in 0..30 {
        tr.epoch(&t).unwrap();
    }
    let (rmse1, _) = tr.evaluate(&t).unwrap();
    assert!(rmse1 < rmse0 * 0.7, "toy: {rmse0} -> {rmse1}");
}

#[test]
fn all_algorithms_converge_cpu() {
    let tensor = generate(&SynthConfig::order_sweep(3, 32, 3_000, 9));
    let (train, test) = train_test_split(&tensor, 0.2, 9);
    for algo in [Algo::Plus, Algo::FastTucker, Algo::FasterTucker] {
        let mut cfg = TrainConfig::default();
        cfg.backend = Backend::CpuRef;
        cfg.algo = algo;
        let mut tr = Trainer::new(&train, cfg).unwrap();
        let (rmse0, _) = tr.evaluate(&test).unwrap();
        for _ in 0..8 {
            tr.epoch(&train).unwrap();
        }
        let (rmse1, _) = tr.evaluate(&test).unwrap();
        assert!(rmse1 < rmse0, "{algo:?}: {rmse0} -> {rmse1}");
    }
}

#[test]
fn plus_converges_faster_than_fasttucker_cpu() {
    // The paper's Fig. 1 claim, as a regression test: after the same number
    // of epochs from the same init, Plus's test RMSE <= FastTucker's.
    let tensor = generate(&SynthConfig::netflix_like(20_000, 13));
    let (train, test) = train_test_split(&tensor, 0.2, 13);
    let mut rmse = std::collections::BTreeMap::new();
    for algo in [Algo::Plus, Algo::FastTucker] {
        let mut cfg = TrainConfig::default();
        cfg.backend = Backend::CpuRef;
        cfg.algo = algo;
        cfg.seed = 99;
        let mut tr = Trainer::new(&train, cfg).unwrap();
        for _ in 0..5 {
            tr.epoch(&train).unwrap();
        }
        let (r, _) = tr.evaluate(&test).unwrap();
        rmse.insert(algo.name(), r);
    }
    assert!(
        rmse["plus"] <= rmse["fasttucker"] * 1.02,
        "plus {} vs fasttucker {}",
        rmse["plus"],
        rmse["fasttucker"]
    );
}

#[test]
fn trainer_rejects_mismatched_tensor() {
    let a = generate(&SynthConfig::order_sweep(3, 32, 1_000, 1));
    let b = generate(&SynthConfig::order_sweep(3, 32, 2_000, 2));
    let mut cfg = TrainConfig::default();
    cfg.backend = Backend::CpuRef;
    let mut tr = Trainer::new(&a, cfg).unwrap();
    assert!(tr.epoch(&b).is_err());
}

#[test]
fn checkpoint_roundtrip_preserves_predictions() {
    let tensor = generate(&SynthConfig::order_sweep(3, 32, 2_000, 17));
    let mut cfg = TrainConfig::default();
    cfg.backend = Backend::CpuRef;
    let mut tr = Trainer::new(&tensor, cfg).unwrap();
    for _ in 0..3 {
        tr.epoch(&tensor).unwrap();
    }
    let dir = std::env::temp_dir().join("ft_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ckpt.ftm");
    tr.model.save(&path).unwrap();
    let loaded = TuckerModel::load(&path).unwrap();
    for e in (0..tensor.nnz()).step_by(137) {
        let c = tensor.coords(e);
        assert_eq!(tr.model.predict_one(c), loaded.predict_one(c));
    }
}

#[test]
fn dataset_io_pipeline() {
    // synth -> write binary -> read -> split -> train one epoch
    let tensor = generate(&SynthConfig::order_sweep(4, 16, 1_500, 21));
    let dir = std::env::temp_dir().join("ft_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("pipeline.ftb");
    io::write_binary(&tensor, &path).unwrap();
    let loaded = io::read_auto(Path::new(&path)).unwrap();
    assert_eq!(loaded.nnz(), tensor.nnz());
    let (train, _) = train_test_split(&loaded, 0.1, 2);
    let mut cfg = TrainConfig::default();
    cfg.backend = Backend::CpuRef;
    let mut tr = Trainer::new(&train, cfg).unwrap();
    tr.epoch(&train).unwrap();
}

#[test]
fn divergence_guard_param_norm() {
    // A hostile learning rate must produce a detectable (finite-or-not)
    // signal rather than silently corrupting state.
    let tensor = generate(&SynthConfig::order_sweep(3, 32, 2_000, 23));
    let mut cfg = TrainConfig::default();
    cfg.backend = Backend::CpuRef;
    cfg.hyper.lr_a = 10.0; // absurd
    let mut tr = Trainer::new(&tensor, cfg).unwrap();
    let _ = tr.epoch(&tensor);
    let norm = tr.model.param_norm();
    // either diverged to inf/nan (caught) or exploded hugely — both detectable
    assert!(!norm.is_finite() || norm > 1e3, "norm {norm}");
}
