//! TCP distributed-backend acceptance suite.
//!
//! The channel backend's guarantees, re-pinned over real loopback
//! sockets:
//!
//! 1. one worker over TCP is **byte-identical** (FTM1 bytes included)
//!    to the serial trainer — the sockets, JSON frames, and model
//!    payloads add nothing and lose nothing;
//! 2. a worker killed mid-round (socket dropped, heartbeats stop) is
//!    evicted by the heartbeat timeout and the run completes on the
//!    survivor, every round accounted for;
//! 3. hostile peers — garbage handshakes, oversize frames, ids beyond
//!    2^53, binary noise — are dropped without consuming member ids,
//!    and a real run proceeds untouched on the same listener;
//! 4. a worker facing a broken or silent coordinator fails loudly
//!    (bad-welcome / protocol-mismatch / timeout errors), never wedges.

mod common;

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use fasttucker::coordinator::{Backend, TrainConfig};
use fasttucker::dist::{
    run_coordinator_on, run_worker, CoordinatorState, DistPhase, Fault, JoinOpts,
};
use fasttucker::model::TuckerModel;
use fasttucker::session::{
    DataSource, NullObserver, Observer, RunSpec, Schedule, Session, SynthPreset, SynthSpec,
};

/// A synthetic spec the serial Session and both distributed backends
/// accept: small order-3 tensor, deterministic CPU reference backend.
fn base_spec(nnz: usize, epochs: usize) -> RunSpec {
    RunSpec {
        data: DataSource::Synth(SynthSpec {
            preset: SynthPreset::Order,
            order: 3,
            dim: 24,
            nnz,
            seed: 11,
        }),
        train: TrainConfig {
            backend: Backend::CpuRef,
            ..TrainConfig::default()
        },
        schedule: Schedule {
            epochs,
            eval_every: 0,
            test_frac: 0.0,
            ..Schedule::default()
        },
        metrics: None,
    }
}

fn assert_models_bit_identical(a: &TuckerModel, b: &TuckerModel) {
    assert_eq!(a.dims, b.dims);
    assert_eq!((a.j, a.r), (b.j, b.r));
    for (n, (fa, fb)) in a.factors.iter().zip(&b.factors).enumerate() {
        assert!(
            fa.iter().zip(fb).all(|(x, y)| x.to_bits() == y.to_bits()),
            "factor {n} differs"
        );
    }
    for (n, (ca, cb)) in a.cores.iter().zip(&b.cores).enumerate() {
        assert!(
            ca.iter().zip(cb).all(|(x, y)| x.to_bits() == y.to_bits()),
            "core {n} differs"
        );
    }
}

/// Records every coordinator state the driver surfaces through
/// [`Observer::on_round`].
#[derive(Default)]
struct StateTrace {
    states: Vec<CoordinatorState>,
}

impl Observer for StateTrace {
    fn on_round(&mut self, state: &CoordinatorState) {
        self.states.push(state.clone());
    }
}

/// An ephemeral loopback listener plus its dialable address.
fn loopback_listener() -> (TcpListener, String) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    (listener, addr)
}

// ======================================================================
// acceptance: byte parity and fault recovery over real sockets
// ======================================================================

/// Acceptance criterion: one worker over loopback TCP produces the
/// exact FTM1 bytes of the serial trainer.  The handshake, the JSON
/// control frames, the hyper extension field, and the binary model
/// payloads all round-trip bit patterns (the CI `dist-tcp-smoke` job
/// `cmp`-checks the same thing end to end via the CLI).
#[test]
fn one_tcp_worker_matches_serial_bytes() {
    let mut spec = base_spec(2_000, 3);

    let mut session = Session::from_spec(&spec).unwrap();
    session.run(&mut NullObserver).unwrap();
    let serial = session.trainer_mut().model.clone();

    spec.train.workers = 1;
    let (listener, addr) = loopback_listener();
    let (run, summary) = std::thread::scope(|s| {
        let coord = s.spawn(|| run_coordinator_on(&spec, listener, &mut NullObserver));
        let worker = s.spawn(|| run_worker(&addr, &JoinOpts::default()));
        (coord.join().unwrap(), worker.join().unwrap())
    });
    let run = run.unwrap();
    let summary = summary.unwrap();

    assert_eq!(run.final_state.phase, DistPhase::Done);
    assert_eq!(run.report.epochs_run, 3);
    assert_eq!(summary.member, 1);
    assert_eq!(summary.rounds, 3);
    assert_models_bit_identical(&serial, &run.model);
    // the checkpoint encodings match byte for byte, not just bit-wise
    // field by field
    assert!(
        serial.to_bytes() == run.model.to_bytes(),
        "FTM1 bytes differ between serial and TCP runs"
    );
}

/// Acceptance criterion: a worker killed mid-round (simulated `kill -9`:
/// no StepComplete, heartbeats stop, socket dropped) is evicted by the
/// heartbeat timeout and the run completes every round on the survivor.
#[test]
fn tcp_worker_killed_mid_round_is_evicted_and_the_run_completes() {
    let mut spec = base_spec(3_000, 4);
    spec.schedule.eval_every = 1;
    spec.schedule.test_frac = 0.25;

    let mut session = Session::from_spec(&spec).unwrap();
    let serial_rmse = session.run(&mut NullObserver).unwrap().final_rmse.unwrap();

    spec.train.workers = 2;
    let (listener, addr) = loopback_listener();
    let doomed_opts = JoinOpts {
        fault: Some(Fault { round: 1 }),
        ..JoinOpts::default()
    };
    let mut trace = StateTrace::default();
    let (run, healthy, doomed) = std::thread::scope(|s| {
        let coord = s.spawn(|| run_coordinator_on(&spec, listener, &mut trace));
        let healthy = s.spawn(|| run_worker(&addr, &JoinOpts::default()));
        let doomed = s.spawn(|| run_worker(&addr, &doomed_opts));
        (
            coord.join().unwrap(),
            healthy.join().unwrap(),
            doomed.join().unwrap(),
        )
    });
    let run = run.unwrap();
    let healthy = healthy.unwrap();
    let doomed = doomed.unwrap();

    // the run completed every round despite losing a worker mid-epoch
    assert_eq!(run.final_state.phase, DistPhase::Done);
    assert_eq!(run.report.epochs_run, 4);
    assert_eq!(
        run.final_state.members,
        vec![healthy.member],
        "only the survivor may remain"
    );
    assert_eq!(healthy.rounds, 4, "the survivor trains every round");
    assert_eq!(doomed.rounds, 1, "the victim dies inside round 1");
    assert!(
        trace.states.iter().any(|s| s.members.len() == 2),
        "both members should appear before the fault"
    );
    assert!(
        trace.states.iter().any(|s| s.members.len() == 1),
        "the eviction should surface through on_round"
    );

    // quality: the survivor still converges toward the serial plateau
    // (same 35% headroom rationale as the channel backend's fault test:
    // the victim's round-1 updates are lost outright)
    let dist_rmse = run.report.final_rmse.unwrap();
    let init_rmse = run.report.history[0].rmse.unwrap();
    assert!(dist_rmse < init_rmse, "faulted run never improved");
    assert!(
        (dist_rmse - serial_rmse).abs() <= 0.35 * serial_rmse,
        "faulted rmse {dist_rmse} strays from serial {serial_rmse}"
    );
}

// ======================================================================
// adversarial frames against the coordinator
// ======================================================================

/// Every hostile handshake in the shared corpus is dropped without
/// consuming a member id, without wedging the accept loop, and without
/// leaking a welcome — then a real worker joins the same listener and
/// the run completes normally.
#[test]
fn hostile_handshakes_are_dropped_and_the_run_survives() {
    let mut spec = base_spec(1_500, 2);
    spec.train.workers = 1;
    let (listener, addr) = loopback_listener();

    std::thread::scope(|s| {
        let coord = s.spawn(|| run_coordinator_on(&spec, listener, &mut NullObserver));

        for (i, frame) in common::malformed_control_frames().into_iter().enumerate() {
            let mut sock = TcpStream::connect(&addr).unwrap();
            sock.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
            // writes may legally fail mid-way: the coordinator drops
            // oversize peers before they finish sending
            let _ = sock.write_all(&frame);
            let _ = sock.shutdown(Shutdown::Write);
            let mut sink = Vec::new();
            match sock.read_to_end(&mut sink) {
                Ok(_) => {}
                // a reset is a loud drop too; only a wedge (timeout) fails
                Err(e) => assert!(
                    !matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ),
                    "hostile frame {i} wedged the coordinator: {e}"
                ),
            }
            assert!(
                !String::from_utf8_lossy(&sink).contains("\"welcome\""),
                "hostile frame {i} was welcomed: {sink:?}"
            );
        }

        // the same listener still serves a real run, and the hostile
        // peers consumed no member ids
        let worker = s.spawn(|| run_worker(&addr, &JoinOpts::default()));
        let run = coord.join().unwrap().unwrap();
        let summary = worker.join().unwrap().unwrap();
        assert_eq!(run.final_state.phase, DistPhase::Done);
        assert_eq!(run.report.epochs_run, 2);
        assert_eq!(
            summary.member, 1,
            "hostile peers must not consume member ids"
        );
        assert_eq!(run.final_state.members, vec![1]);
    });
}

// ======================================================================
// adversarial coordinators against the worker
// ======================================================================

/// Accept one connection, drain the peer's handshake, answer `reply`,
/// then hold the socket open until the peer hangs up.
fn fake_coordinator(listener: TcpListener, reply: Vec<u8>) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let (mut sock, _) = listener.accept().unwrap();
        let mut buf = [0u8; 1024];
        let _ = sock.read(&mut buf); // the worker's join line
        let _ = sock.write_all(&reply);
        let _ = sock.shutdown(Shutdown::Write);
        // wait for the peer to close so the reply is never reset away
        while matches!(sock.read(&mut buf), Ok(n) if n > 0) {}
    })
}

/// A worker pointed at a broken coordinator errors loudly — garbage,
/// wrong-kind, and wrong-protocol welcomes each name their failure.
#[test]
fn worker_rejects_bad_welcomes_loudly() {
    let cases: &[(&[u8], &str)] = &[
        (b"this is not json\n", "welcome"),
        (b"{\"kind\":\"begin_round\",\"round\":0}\n", "welcome"),
        (
            b"{\"kind\":\"welcome\",\"proto\":99,\"member\":1,\"section_entries\":8}\n",
            "protocol version mismatch",
        ),
    ];
    for (reply, needle) in cases {
        let (listener, addr) = loopback_listener();
        let fake = fake_coordinator(listener, reply.to_vec());
        let err = run_worker(&addr, &JoinOpts::default()).unwrap_err();
        assert!(
            format!("{err:#}").contains(needle),
            "reply {:?} should fail with {needle:?}, got: {err:#}",
            String::from_utf8_lossy(reply)
        );
        fake.join().unwrap();
    }
}

/// Satellite pin: the worker reuses the serving client's bounded-read
/// mechanism, so a silent coordinator surfaces as a loud, prompt
/// timeout error — never a wedged process.
#[test]
fn worker_timeout_is_loud_not_a_wedge() {
    // bound but never accept: the connect succeeds (backlog) and then
    // the handshake read must hit the configured timeout
    let (listener, addr) = loopback_listener();
    let opts = JoinOpts {
        timeout: Some(Duration::from_millis(200)),
        ..JoinOpts::default()
    };
    let t0 = Instant::now();
    let err = run_worker(&addr, &opts).unwrap_err();
    assert!(
        format!("{err:#}").contains("timed out"),
        "expected a timeout error, got: {err:#}"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "a 200 ms timeout took {:?}",
        t0.elapsed()
    );
    drop(listener);
}
