//! Closed-loop SLO curve for the TCP serving tier (`BENCH_serve_slo.json`).
//!
//! Trains a small Netflix-like model in process, publishes it into a
//! [`Registry`], binds a [`NetServer`] on a loopback port, and walks an
//! offered-QPS ladder with the [`run_slo`] harness over real sockets —
//! the full client → poll thread → admission → worker → response path,
//! framing and syscalls included.  One `BENCH_JSON` row per ladder step
//! carrying offered vs achieved QPS, p50/p95/p99 client-observed latency,
//! and the shed / deadline-miss counts that locate the saturation knee.
//!
//! Run: `cargo bench --bench serve_slo` (BENCH_QUICK=1 shrinks it).

use fasttucker::coordinator::{Backend, TrainConfig};
use fasttucker::serve::net::{run_slo, slo_header, NetConfig, NetServer, SloConfig};
use fasttucker::serve::Registry;
use fasttucker::session::{NullObserver, Schedule, Session};
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::util::json::Json;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (nnz, epochs, steps, step_secs) = if quick {
        (20_000, 1, vec![100u64, 400], 1.0)
    } else {
        (120_000, 3, vec![200u64, 800, 3200, 12800], 3.0)
    };

    let train = generate(&SynthConfig::netflix_like(nnz, 7));
    let cfg = TrainConfig {
        backend: Backend::ParallelCpu,
        ..TrainConfig::default()
    };
    let schedule = Schedule {
        epochs,
        eval_every: 0,
        test_frac: 0.0,
        ..Schedule::default()
    };
    let mut session = Session::with_owned_tensor(train, cfg, schedule)?;
    session.run(&mut NullObserver)?;

    let registry = Registry::shared();
    registry.publish("default", session.snapshot());
    let server = NetServer::bind("127.0.0.1:0", registry, NetConfig::default())?;
    let addr = server.local_addr().to_string();

    let slo = SloConfig {
        addr,
        steps,
        step_duration: std::time::Duration::from_secs_f64(step_secs),
        ..SloConfig::default()
    };
    let rows = run_slo(&slo)?;

    let stats = server.shutdown();

    println!("\n=== Serve SLO — netflix-like, {nnz} nnz, {} connections ===", slo.connections);
    println!("{}", slo_header());
    for row in &rows {
        println!("{}", row.render());
    }
    println!(
        "server totals: {} frames, {} requests, {} shed, {} deadline-missed",
        stats.frames, stats.requests, stats.shed, stats.deadline_missed
    );
    for row in &rows {
        // label each scraped row by its ladder step, matching the
        // label-keyed row convention of the other benches
        let mut obj = match row.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        obj.insert(
            "label".to_string(),
            fasttucker::util::json::s(&format!("qps_{}", row.offered_qps as u64)),
        );
        println!("BENCH_JSON {}", Json::Obj(obj).dump());
    }
    Ok(())
}
