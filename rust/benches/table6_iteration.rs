//! Table 6 — single-iteration running time of every algorithm, factor and
//! core phases, on the Netflix-like and Yahoo!Music-like surrogates, with
//! speedups relative to the FastTucker CC baseline (the paper's
//! cuFastTucker row).
//!
//! Paper shape to reproduce: Plus_TC fastest in both phases; Plus_CC slower
//! than FasterTucker but ~3x faster than FastTucker_CC; _TC variants beat
//! their _CC counterparts except FasterTucker (minimal matmul work).

use fasttucker::bench::{bench_phases, report, Row};
use fasttucker::coordinator::{Algo, TrainConfig, Variant};
use fasttucker::synth::{generate, SynthConfig};

fn main() -> anyhow::Result<()> {
    let (warmup, reps, nnz) = knobs();
    for (ds, cfg_t) in [
        ("netflix-like", SynthConfig::netflix_like(nnz, 7)),
        ("yahoo-like", SynthConfig::yahoo_like(nnz, 8)),
    ] {
        let train = generate(&cfg_t);
        let mut rows: Vec<Row> = Vec::new();
        for (algo, variant) in [
            (Algo::FastTucker, Variant::Cc),
            (Algo::FastTucker, Variant::Tc),
            (Algo::FasterTucker, Variant::Cc),
            (Algo::FasterTucker, Variant::Tc),
            (Algo::FasterTuckerCoo, Variant::Cc),
            (Algo::FasterTuckerCoo, Variant::Tc),
            (Algo::Plus, Variant::Cc),
            (Algo::Plus, Variant::Tc),
        ] {
            let mut cfg = TrainConfig::default();
            cfg.algo = algo;
            cfg.variant = variant;
            let label = format!("{}_{}", algo.name(), variant.suffix());
            rows.extend(bench_phases(&label, &train, cfg, warmup, reps)?);
        }
        // speedup vs fasttucker_cc per phase (paper's baseline column)
        for phase in ["factor", "core"] {
            let base = rows
                .iter()
                .find(|r| r.label == format!("fasttucker_cc/{phase}"))
                .map(|r| r.median_s)
                .unwrap_or(f64::NAN);
            let updates: Vec<(String, f64)> = rows
                .iter()
                .filter(|r| r.label.ends_with(&format!("/{phase}")))
                .map(|r| (r.label.clone(), base / r.median_s))
                .collect();
            for (label, speedup) in updates {
                if let Some(r) = rows.iter_mut().find(|r| r.label == label) {
                    r.extra.push(("speedup_vs_fasttucker_cc".into(), speedup));
                }
            }
        }
        report(&format!("Table 6 — single-iteration time ({ds})"), &rows);
    }
    Ok(())
}

fn knobs() -> (usize, usize, usize) {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    if quick {
        (0, 1, 20_000)
    } else {
        (1, 3, 80_000)
    }
}
