//! Fig. 2 — single-iteration running time vs tensor order (3..8) on the
//! synthetic family, for all three algorithms (TC variants).
//!
//! Paper shape: Plus lowest everywhere and growing ~linearly with order;
//! FastTucker growing fastest (its per-mode recompute is O(N^2) in the
//! mode loop); FasterTucker in between but with heavy fiber padding.

use fasttucker::bench::{bench_phases, report, Row};
use fasttucker::coordinator::{Algo, TrainConfig};
use fasttucker::synth::{generate, SynthConfig};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (warmup, reps, nnz) = if quick { (0, 1, 8_000) } else { (1, 3, 30_000) };
    let mut rows: Vec<Row> = Vec::new();
    for order in 3..=8 {
        let train = generate(&SynthConfig::order_sweep(order, 64, nnz, 3));
        for algo in [Algo::FastTucker, Algo::FasterTucker, Algo::FasterTuckerCoo, Algo::Plus] {
            let mut cfg = TrainConfig::default();
            cfg.algo = algo;
            let label = format!("n{order}/{}", algo.name());
            rows.extend(bench_phases(&label, &train, cfg, warmup, reps)?);
        }
    }
    report("Fig. 2 — iteration time vs order (synthetic)", &rows);
    Ok(())
}
