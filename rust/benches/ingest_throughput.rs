//! Ingest + paged-read throughput of the out-of-core data layer.
//!
//! Rows:
//! * `ingest_text` / `ingest_ftb1` — streaming conversion into the FTB2
//!   store (constant memory; the `mentries_per_s` extra is the headline
//!   number, `mb_per_s` the disk-side view).
//! * `paged_scan` vs `ram_scan` — a full sequential gather through the
//!   [`fasttucker::data::PagedTensor`] LRU page cache vs the same gather
//!   from RAM: the price of staying out of core on the staging path
//!   (the training pipeline hides it behind the double buffer).
//!
//! Run: `cargo bench --bench ingest_throughput` (BENCH_QUICK=1 shrinks it).
//! No artifacts needed.  Record results in BENCHMARKS.md conventions.

use fasttucker::bench::{measure, report, Row};
use fasttucker::data::{ingest_file, PagedTensor, TensorView};
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::tensor::io;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (warmup, reps, nnz) = if quick { (1, 3, 50_000) } else { (1, 5, 500_000) };
    let dir = std::env::temp_dir().join("ft_ingest_bench");
    std::fs::create_dir_all(&dir)?;

    let tensor = generate(&SynthConfig::netflix_like(nnz, 7));
    let text = dir.join("in.coo");
    let ftb1 = dir.join("in.ftb");
    io::write_text(&tensor, &text)?;
    io::write_binary(&tensor, &ftb1)?;

    let mut rows: Vec<Row> = Vec::new();
    for (label, input) in [("ingest_text", &text), ("ingest_ftb1", &ftb1)] {
        let out = dir.join(format!("{label}.ftb2"));
        let mut bytes = 0u64;
        let mut row = measure(label, warmup, reps, || {
            let stats = ingest_file(input, &out, 8192).expect("ingest");
            bytes = stats.out_bytes;
            stats.nnz as f64
        });
        row.extra.push(("mentries_per_s".into(), nnz as f64 / row.median_s / 1e6));
        row.extra.push(("mb_per_s".into(), bytes as f64 / row.median_s / 1e6));
        rows.push(row);
    }

    let store = dir.join("ingest_text.ftb2");
    let paged = PagedTensor::open(&store)?;
    let order = tensor.order();
    let mut coords = vec![0u32; order];
    let mut row = measure("paged_scan", warmup, reps, || {
        let mut acc = 0f64;
        for e in 0..TensorView::nnz(&paged) {
            acc += paged.load_entry(e, &mut coords) as f64;
        }
        acc
    });
    row.extra.push(("mentries_per_s".into(), nnz as f64 / row.median_s / 1e6));
    rows.push(row);

    let mut row = measure("ram_scan", warmup, reps, || {
        let mut acc = 0f64;
        for e in 0..tensor.nnz() {
            acc += tensor.load_entry(e, &mut coords) as f64;
        }
        acc
    });
    row.extra.push(("mentries_per_s".into(), nnz as f64 / row.median_s / 1e6));
    rows.push(row);

    let (hits, loads) = paged.cache_stats();
    println!("page cache after scans: {hits} hits / {loads} loads");
    report(
        &format!("Ingest + paged-read throughput — netflix-like, {nnz} nnz"),
        &rows,
    );
    Ok(())
}
