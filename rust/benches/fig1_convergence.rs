//! Fig. 1 — convergence curves: test RMSE and MAE per iteration for
//! FastTuckerPlus vs the FastTucker / FasterTucker baselines, identical
//! random init, on both real-dataset surrogates.
//!
//! Each curve is one scheduled [`Session`] run (per-epoch evaluation over
//! a 20% held-out split); the bench just formats the recorded history.
//!
//! Paper shape: all algorithms converge to a similar floor, but Plus (the
//! two-block non-convex SGD) reaches it in clearly fewer iterations —
//! the local-search-beats-convex-relaxation claim.

use fasttucker::coordinator::{Algo, Backend, TrainConfig};
use fasttucker::session::{NullObserver, Schedule, Session};
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::util::json::{self, Json};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (nnz, epochs) = if quick { (20_000, 4) } else { (80_000, 15) };
    for (ds, cfg_t) in [
        ("netflix-like", SynthConfig::netflix_like(nnz, 7)),
        ("yahoo-like", SynthConfig::yahoo_like(nnz, 8)),
    ] {
        let tensor = generate(&cfg_t);
        println!("\n=== Fig. 1 — convergence ({ds}) ===");
        println!("{:<16} {:>5} {:>9} {:>9}", "algorithm", "epoch", "rmse", "mae");
        for algo in [Algo::Plus, Algo::FastTucker, Algo::FasterTucker] {
            // HLO backend for Plus when the artifacts exist (the system
            // under test); the baselines' faithful sequential-update
            // semantics live in cpu_ref.
            let base = TrainConfig::default();
            let backend = if algo == Algo::Plus {
                let b = base.auto_backend();
                if b != Backend::Hlo {
                    eprintln!(
                        "note: no artifacts — plus curve runs on the {} backend, \
                         not the HLO system under test",
                        b.name()
                    );
                }
                b
            } else {
                Backend::CpuRef
            };
            let cfg = TrainConfig {
                algo,
                backend,
                ..base
            };
            let schedule = Schedule {
                epochs,
                eval_every: 1,
                test_frac: 0.2,
                ..Schedule::default()
            };
            let mut session = Session::with_tensor(&tensor, cfg, schedule)?;
            let report = session.run(&mut NullObserver)?;
            let mut series: Vec<Json> = Vec::new();
            for ev in &report.history {
                let (Some(rmse), Some(mae)) = (ev.rmse, ev.mae) else {
                    continue;
                };
                println!("{:<16} {:>5} {:>9.4} {:>9.4}", algo.name(), ev.epoch, rmse, mae);
                if ev.epoch > 0 {
                    series.push(json::obj(vec![
                        ("epoch", json::num(ev.epoch as f64)),
                        ("rmse", json::num(rmse)),
                        ("mae", json::num(mae)),
                    ]));
                }
            }
            println!(
                "BENCH_JSON {}",
                json::obj(vec![
                    ("figure", json::s("fig1")),
                    ("dataset", json::s(ds)),
                    ("algo", json::s(algo.name())),
                    ("backend", json::s(backend.name())),
                    ("series", json::arr(series)),
                ])
                .dump()
            );
        }
    }
    Ok(())
}
