//! Fig. 1 — convergence curves: test RMSE and MAE per iteration for
//! FastTuckerPlus vs the FastTucker / FasterTucker baselines, identical
//! random init, on both real-dataset surrogates.
//!
//! Paper shape: all algorithms converge to a similar floor, but Plus (the
//! two-block non-convex SGD) reaches it in clearly fewer iterations —
//! the local-search-beats-convex-relaxation claim.

use fasttucker::coordinator::{Algo, Backend, TrainConfig, Trainer};
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::tensor::split::train_test_split;
use fasttucker::util::json::{self, Json};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (nnz, epochs) = if quick { (20_000, 4) } else { (80_000, 15) };
    for (ds, cfg_t) in [
        ("netflix-like", SynthConfig::netflix_like(nnz, 7)),
        ("yahoo-like", SynthConfig::yahoo_like(nnz, 8)),
    ] {
        let tensor = generate(&cfg_t);
        let (train, test) = train_test_split(&tensor, 0.2, 7);
        println!("\n=== Fig. 1 — convergence ({ds}) ===");
        println!("{:<16} {:>5} {:>9} {:>9}", "algorithm", "epoch", "rmse", "mae");
        for algo in [Algo::Plus, Algo::FastTucker, Algo::FasterTucker] {
            let mut cfg = TrainConfig::default();
            cfg.algo = algo;
            // HLO backend for Plus (the system under test); the baselines'
            // faithful sequential-update semantics live in cpu_ref.
            cfg.backend = if algo == Algo::Plus { Backend::Hlo } else { Backend::CpuRef };
            let mut trainer = Trainer::new(&train, cfg)?;
            let mut series: Vec<Json> = Vec::new();
            let (rmse0, mae0) = trainer.evaluate(&test)?;
            println!("{:<16} {:>5} {:>9.4} {:>9.4}", algo.name(), 0, rmse0, mae0);
            for epoch in 1..=epochs {
                trainer.epoch(&train)?;
                let (rmse, mae) = trainer.evaluate(&test)?;
                println!("{:<16} {:>5} {:>9.4} {:>9.4}", algo.name(), epoch, rmse, mae);
                series.push(json::obj(vec![
                    ("epoch", json::num(epoch as f64)),
                    ("rmse", json::num(rmse)),
                    ("mae", json::num(mae)),
                ]));
            }
            println!(
                "BENCH_JSON {}",
                json::obj(vec![
                    ("figure", json::s("fig1")),
                    ("dataset", json::s(ds)),
                    ("algo", json::s(algo.name())),
                    ("series", json::arr(series)),
                ])
                .dump()
            );
        }
    }
    Ok(())
}
