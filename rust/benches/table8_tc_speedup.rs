//! Table 8 — speedup from the matrix-unit path: time(CC) / time(TC) per
//! algorithm and phase on the real-dataset surrogates.
//!
//! Paper shape: large speedups for FastTucker and Plus (their inner loop is
//! dominated by MXU-tileable matmuls); ~1x or below for FasterTucker
//! (memory-bound, almost no matmul work to accelerate).

use fasttucker::bench::{bench_phases, report, Row};
use fasttucker::coordinator::{Algo, TrainConfig, Variant};
use fasttucker::synth::{generate, SynthConfig};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (warmup, reps, nnz) = if quick { (0, 1, 20_000) } else { (1, 3, 80_000) };
    for (ds, cfg_t) in [
        ("netflix-like", SynthConfig::netflix_like(nnz, 7)),
        ("yahoo-like", SynthConfig::yahoo_like(nnz, 8)),
    ] {
        let train = generate(&cfg_t);
        let mut rows: Vec<Row> = Vec::new();
        for algo in [Algo::FastTucker, Algo::FasterTucker, Algo::FasterTuckerCoo, Algo::Plus] {
            let mut cc_rows = Vec::new();
            for variant in [Variant::Cc, Variant::Tc] {
                let mut cfg = TrainConfig::default();
                cfg.algo = algo;
                cfg.variant = variant;
                let label = format!("{}_{}", algo.name(), variant.suffix());
                let rs = bench_phases(&label, &train, cfg, warmup, reps)?;
                if variant == Variant::Cc {
                    cc_rows = rs.clone();
                } else {
                    for (mut tc, cc) in rs.into_iter().zip(cc_rows.drain(..)) {
                        tc.extra
                            .push(("tc_speedup".into(), cc.median_s / tc.median_s));
                        rows.push(cc);
                        rows.push(tc);
                    }
                    continue;
                }
            }
        }
        report(
            &format!("Table 8 — Tensor-Core (MXU) speedup ({ds}); see tc_speedup extras"),
            &rows,
        );
    }
    Ok(())
}
