//! Table 8 — speedup from the matrix-unit path: time(CC) / time(TC) per
//! algorithm and phase on the real-dataset surrogates.
//!
//! Paper shape: large speedups for FastTucker and Plus (their inner loop is
//! dominated by MXU-tileable matmuls); ~1x or below for FasterTucker
//! (memory-bound, almost no matmul work to accelerate).
//!
//! The TC/CC section needs the compiled HLO artifacts, so it is gated on
//! [`TrainConfig::hlo_available`] — a clean checkout still produces the
//! CPU analog: scalar vs tiled vs SIMD kernel tiers per algorithm, with
//! `speedup_vs_scalar` extras (the CPU counterpart of the tensor-core
//! speedup claim: how much the wide-unit path buys over scalar issue).

use fasttucker::bench::{bench_phases, report, Row};
use fasttucker::coordinator::{Algo, Backend, TrainConfig, Variant};
use fasttucker::kernel::KernelPolicy;
use fasttucker::synth::{generate, SynthConfig};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (warmup, reps, nnz) = if quick { (0, 1, 20_000) } else { (1, 3, 80_000) };
    let hlo = TrainConfig::default().hlo_available();
    if !hlo {
        println!("HLO artifacts not found — skipping the TC/CC section (run `make artifacts`)");
    }
    for (ds, cfg_t) in [
        ("netflix-like", SynthConfig::netflix_like(nnz, 7)),
        ("yahoo-like", SynthConfig::yahoo_like(nnz, 8)),
    ] {
        let train = generate(&cfg_t);
        if hlo {
            let mut rows: Vec<Row> = Vec::new();
            for algo in [Algo::FastTucker, Algo::FasterTucker, Algo::FasterTuckerCoo, Algo::Plus] {
                let mut cc_rows = Vec::new();
                for variant in [Variant::Cc, Variant::Tc] {
                    let mut cfg = TrainConfig::default();
                    cfg.algo = algo;
                    cfg.variant = variant;
                    let label = format!("{}_{}", algo.name(), variant.suffix());
                    let rs = bench_phases(&label, &train, cfg, warmup, reps)?;
                    if variant == Variant::Cc {
                        cc_rows = rs.clone();
                    } else {
                        for (mut tc, cc) in rs.into_iter().zip(cc_rows.drain(..)) {
                            tc.extra
                                .push(("tc_speedup".into(), cc.median_s / tc.median_s));
                            rows.push(cc);
                            rows.push(tc);
                        }
                        continue;
                    }
                }
            }
            report(
                &format!("Table 8 — Tensor-Core (MXU) speedup ({ds}); see tc_speedup extras"),
                &rows,
            );
        }

        // CPU kernel-tier analog: scalar vs tiled vs runtime-dispatched SIMD
        let mut rows: Vec<Row> = Vec::new();
        println!(
            "simd backend: {}",
            fasttucker::kernel::simd::active().name()
        );
        for algo in [Algo::FastTucker, Algo::FasterTucker, Algo::Plus] {
            let mut scalar_rows = Vec::new();
            for policy in [KernelPolicy::Scalar, KernelPolicy::Tiled, KernelPolicy::Simd] {
                let mut cfg = TrainConfig::default();
                cfg.backend = Backend::CpuRef;
                cfg.algo = algo;
                cfg.cpu_kernel = policy;
                let label = format!("{}_{}", algo.name(), policy.name());
                let mut rs = bench_phases(&label, &train, cfg, warmup, reps)?;
                if policy == KernelPolicy::Scalar {
                    scalar_rows = rs.clone();
                } else {
                    for (row, base) in rs.iter_mut().zip(&scalar_rows) {
                        row.extra
                            .push(("speedup_vs_scalar".into(), base.median_s / row.median_s));
                    }
                }
                rows.extend(rs);
            }
        }
        report(
            &format!("Table 8 analog — CPU kernel tiers ({ds}); see speedup_vs_scalar extras"),
            &rows,
        );
    }
    Ok(())
}
