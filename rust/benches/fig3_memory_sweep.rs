//! Fig. 3 — memory-access time vs tensor order (3..8).
//!
//! Paper shape: Plus has both the smallest traffic time and the slowest
//! growth rate with order; FasterTucker overtakes FasterTuckerCOO-like
//! behaviour at order >= 4 because fibers get sparser.

use fasttucker::bench::{bench_phases, measure_bandwidth, report, Row};
use fasttucker::coordinator::{Algo, TrainConfig};
use fasttucker::cost;
use fasttucker::synth::{generate, SynthConfig};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (warmup, reps, nnz) = if quick { (0, 1, 8_000) } else { (1, 3, 30_000) };
    let bw = measure_bandwidth();
    let mut rows: Vec<Row> = Vec::new();
    for order in 3..=8 {
        let train = generate(&SynthConfig::order_sweep(order, 64, nnz, 3));
        let shape = cost::Shape { n: order, j: 16, r: 16, m: 16 };
        for algo in [Algo::FastTucker, Algo::FasterTucker, Algo::FasterTuckerCoo, Algo::Plus] {
            let mut cfg = TrainConfig::default();
            cfg.algo = algo;
            let label = format!("n{order}/{}", algo.name());
            let mut rs = bench_phases(&label, &train, cfg, warmup, reps)?;
            for r in &mut rs {
                if let Some((_, mem)) = r.extra.iter().find(|(k, _)| k == "memory_s") {
                    r.median_s = *mem;
                }
                r.extra.push((
                    "analytic_mem_s".into(),
                    cost::memory_time_s(algo.cost_algo(), shape, train.nnz(), bw),
                ));
            }
            rows.extend(rs);
        }
    }
    report("Fig. 3 — memory-access time vs order (median_s = measured)", &rows);
    Ok(())
}
