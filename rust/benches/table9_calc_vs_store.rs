//! Table 9 — "replace memory access with calculation" (§5.6): for
//! FastTuckerPlus, compare recomputing C_Ψ^(n) on the matrix unit
//! (Calculation) against precomputing C^(n) and reading rows (Storage),
//! in both kernel variants.
//!
//! Paper shape: under the CC (vector/scalar) path Storage wins — the
//! recompute is expensive; under the TC (MXU) path Calculation wins — the
//! matrix unit recomputes faster than memory can deliver the stored rows.
//! This crossover is the paper's central systems claim.

use fasttucker::bench::{bench_phases, report, Row};
use fasttucker::coordinator::{Strategy, TrainConfig, Variant};
use fasttucker::synth::{generate, SynthConfig};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (warmup, reps, nnz) = if quick { (0, 1, 20_000) } else { (1, 3, 80_000) };
    for (ds, cfg_t) in [
        ("netflix-like", SynthConfig::netflix_like(nnz, 7)),
        ("yahoo-like", SynthConfig::yahoo_like(nnz, 8)),
    ] {
        let train = generate(&cfg_t);
        let mut rows: Vec<Row> = Vec::new();
        for variant in [Variant::Cc, Variant::Tc] {
            for strategy in [Strategy::Calculation, Strategy::Storage] {
                let mut cfg = TrainConfig::default();
                cfg.variant = variant;
                cfg.strategy = strategy;
                let label = format!("plus_{}_{:?}", variant.suffix(), strategy).to_lowercase();
                rows.extend(bench_phases(&label, &train, cfg, warmup, reps)?);
            }
        }
        report(&format!("Table 9 — calculation vs storage ({ds})"), &rows);
    }
    Ok(())
}
