//! Table 7 — memory-access time on the real-dataset surrogates.
//!
//! Two complementary readings (DESIGN.md §3 substitution):
//!   (a) measured host-side parameter traffic (gather + scatter +
//!       C-precompute wall time from PhaseStats.memory());
//!   (b) the paper's own Table-4 traffic counts x measured host bandwidth.
//!
//! Paper shape: FastTucker worst by ~10x; Plus smallest in both phases.

use fasttucker::bench::{bench_phases, measure_bandwidth, report, Row};
use fasttucker::coordinator::{Algo, TrainConfig};
use fasttucker::cost;
use fasttucker::synth::{generate, SynthConfig};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (warmup, reps, nnz) = if quick { (0, 1, 20_000) } else { (1, 3, 80_000) };
    let bw = measure_bandwidth();
    println!("measured host bandwidth: {:.2} GB/s", bw / 1e9);
    for (ds, cfg_t) in [
        ("netflix-like", SynthConfig::netflix_like(nnz, 7)),
        ("yahoo-like", SynthConfig::yahoo_like(nnz, 8)),
    ] {
        let train = generate(&cfg_t);
        let shape = cost::Shape { n: train.order(), j: 16, r: 16, m: 16 };
        let mut rows: Vec<Row> = Vec::new();
        for algo in [Algo::FastTucker, Algo::FasterTucker, Algo::FasterTuckerCoo, Algo::Plus] {
            let mut cfg = TrainConfig::default();
            cfg.algo = algo;
            let mut rs = bench_phases(algo.name(), &train, cfg, warmup, reps)?;
            let analytic = cost::memory_time_s(algo.cost_algo(), shape, train.nnz(), bw);
            for r in &mut rs {
                // report measured memory time as the headline number
                if let Some((_, mem)) = r.extra.iter().find(|(k, _)| k == "memory_s") {
                    let mem = *mem;
                    r.extra.push(("analytic_mem_s".into(), analytic));
                    r.median_s = mem; // Table 7 IS the memory column
                }
            }
            rows.extend(rs);
        }
        report(
            &format!("Table 7 — memory-access time ({ds}; median_s = measured traffic time)"),
            &rows,
        );
    }
    Ok(())
}
