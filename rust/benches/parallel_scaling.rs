//! ParallelCpu vs CpuRef — epoch-time scaling of the Hogwild backend.
//!
//! The paper's core systems claim is that the two-phase SGD parallelizes
//! with negligible coordination; this bench measures the Rust analog:
//! per-epoch (factor + core) wall time of the scalar path at 1 thread
//! (`CpuRef`) vs the Hogwild block-sharded backend at increasing worker
//! counts, on the Netflix-like surrogate.  Reported rows include the
//! speedup vs the serial baseline.
//!
//! Run: `cargo bench --bench parallel_scaling` (BENCH_QUICK=1 shrinks it).
//! Record the printed table in ARCHITECTURE.md §Bench notes when hardware
//! changes.

use fasttucker::bench::{bench_phases, report, Row};
use fasttucker::coordinator::{Backend, TrainConfig};
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::util::pool;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (warmup, reps, nnz) = if quick { (1, 3, 30_000) } else { (2, 7, 150_000) };
    let train = generate(&SynthConfig::netflix_like(nnz, 7));

    let mut rows: Vec<Row> = Vec::new();
    let mut cfg = TrainConfig::default();
    cfg.backend = Backend::CpuRef;
    rows.extend(bench_phases("cpu_ref", &train, cfg.clone(), warmup, reps)?);

    let max_threads = pool::default_threads();
    let mut threads = 2usize;
    while threads <= max_threads {
        cfg.backend = Backend::ParallelCpu;
        cfg.threads = threads;
        let label = format!("parallel_cpu_t{threads}");
        rows.extend(bench_phases(&label, &train, cfg.clone(), warmup, reps)?);
        threads *= 2;
    }

    // speedup vs the serial scalar baseline, per phase
    for phase in ["factor", "core"] {
        let base = rows
            .iter()
            .find(|r| r.label == format!("cpu_ref/{phase}"))
            .map(|r| r.median_s)
            .unwrap_or(f64::NAN);
        let updates: Vec<(String, f64)> = rows
            .iter()
            .filter(|r| r.label.ends_with(&format!("/{phase}")))
            .map(|r| (r.label.clone(), base / r.median_s))
            .collect();
        for (label, speedup) in updates {
            if let Some(r) = rows.iter_mut().find(|r| r.label == label) {
                r.extra.push(("speedup_vs_serial".into(), speedup));
            }
        }
    }

    report(
        &format!("ParallelCpu scaling — netflix-like, {nnz} nnz"),
        &rows,
    );
    Ok(())
}
