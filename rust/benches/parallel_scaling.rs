//! ParallelCpu vs CpuRef — epoch-time scaling of the Hogwild backend, plus
//! the tiled-vs-scalar CPU kernel comparison.
//!
//! The paper's core systems claim is that the two-phase SGD parallelizes
//! with negligible coordination; this bench measures the Rust analog:
//! per-epoch (factor + core) wall time of the CPU path at 1 thread
//! (`CpuRef`) vs the Hogwild block-sharded backend at increasing worker
//! counts, on the Netflix-like surrogate.  The serial configuration is
//! measured three times — with the scalar reference kernels
//! (`--cpu-kernel scalar`), the tiled microkernels (the default), and the
//! runtime-dispatched SIMD tier (`--cpu-kernel simd`; the active backend
//! is printed) — so the table shows the microkernel speedup, the SIMD
//! speedup on top of it, and the thread scaling on top of both.  Reported
//! rows include the speedup vs the scalar serial baseline.
//!
//! Run: `cargo bench --bench parallel_scaling` (BENCH_QUICK=1 shrinks it).
//! Record the printed table in ARCHITECTURE.md §Bench notes when hardware
//! changes.

use fasttucker::bench::{bench_phases, report, Row};
use fasttucker::coordinator::{Backend, TrainConfig};
use fasttucker::kernel::KernelPolicy;
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::util::pool;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (warmup, reps, nnz) = if quick { (1, 3, 30_000) } else { (2, 7, 150_000) };
    let train = generate(&SynthConfig::netflix_like(nnz, 7));

    let mut rows: Vec<Row> = Vec::new();
    let mut cfg = TrainConfig::default();
    cfg.backend = Backend::CpuRef;
    cfg.cpu_kernel = KernelPolicy::Scalar;
    rows.extend(bench_phases("cpu_scalar", &train, cfg.clone(), warmup, reps)?);

    cfg.cpu_kernel = KernelPolicy::Tiled;
    rows.extend(bench_phases("cpu_ref", &train, cfg.clone(), warmup, reps)?);

    println!(
        "simd backend: {}",
        fasttucker::kernel::simd::active().name()
    );
    cfg.cpu_kernel = KernelPolicy::Simd;
    rows.extend(bench_phases("cpu_simd", &train, cfg.clone(), warmup, reps)?);
    cfg.cpu_kernel = KernelPolicy::Tiled;

    let max_threads = pool::default_threads();
    let mut threads = 2usize;
    while threads <= max_threads {
        cfg.backend = Backend::ParallelCpu;
        cfg.threads = threads;
        let label = format!("parallel_cpu_t{threads}");
        rows.extend(bench_phases(&label, &train, cfg.clone(), warmup, reps)?);
        threads *= 2;
    }

    // speedup vs the scalar serial baseline, per phase
    for phase in ["factor", "core"] {
        let base = rows
            .iter()
            .find(|r| r.label == format!("cpu_scalar/{phase}"))
            .map(|r| r.median_s)
            .unwrap_or(f64::NAN);
        let updates: Vec<(String, f64)> = rows
            .iter()
            .filter(|r| r.label.ends_with(&format!("/{phase}")))
            .map(|r| (r.label.clone(), base / r.median_s))
            .collect();
        for (label, speedup) in updates {
            if let Some(r) = rows.iter_mut().find(|r| r.label == label) {
                r.extra.push(("speedup_vs_scalar_serial".into(), speedup));
            }
        }
    }

    report(
        &format!("ParallelCpu scaling — netflix-like, {nnz} nnz"),
        &rows,
    );
    Ok(())
}
