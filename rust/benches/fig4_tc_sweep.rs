//! Fig. 4 — Tensor-Core (MXU-path) speedup vs tensor order (3..8).
//!
//! Paper shape: FastTucker and Plus keep a large TC speedup across orders
//! (growing with order for Plus's core phase); FasterTucker stays ~1x.

use fasttucker::bench::{bench_phases, report, Row};
use fasttucker::coordinator::{Algo, TrainConfig, Variant};
use fasttucker::synth::{generate, SynthConfig};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (warmup, reps, nnz) = if quick { (0, 1, 6_000) } else { (1, 2, 20_000) };
    let mut rows: Vec<Row> = Vec::new();
    for order in 3..=8 {
        let train = generate(&SynthConfig::order_sweep(order, 64, nnz, 3));
        for algo in [Algo::FastTucker, Algo::FasterTucker, Algo::FasterTuckerCoo, Algo::Plus] {
            let mut cc_rows = Vec::new();
            for variant in [Variant::Cc, Variant::Tc] {
                let mut cfg = TrainConfig::default();
                cfg.algo = algo;
                cfg.variant = variant;
                let label = format!("n{order}/{}_{}", algo.name(), variant.suffix());
                let rs = bench_phases(&label, &train, cfg, warmup, reps)?;
                if variant == Variant::Cc {
                    cc_rows = rs;
                } else {
                    for (mut tc, cc) in rs.into_iter().zip(cc_rows.drain(..)) {
                        tc.extra
                            .push(("tc_speedup".into(), cc.median_s / tc.median_s));
                        rows.push(tc);
                    }
                }
            }
        }
    }
    report("Fig. 4 — MXU speedup vs order (tc_speedup extras)", &rows);
    Ok(())
}
