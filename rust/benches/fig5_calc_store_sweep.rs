//! Fig. 5 — calculation vs storage across tensor order (3..8), TC variant.
//!
//! Paper shape: Calculation stays below Storage at every order under the
//! matrix-unit path, and the gap widens with order (more C^(n) tables to
//! precompute and read).

use fasttucker::bench::{bench_phases, report, Row};
use fasttucker::coordinator::{Strategy, TrainConfig, Variant};
use fasttucker::synth::{generate, SynthConfig};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (warmup, reps, nnz) = if quick { (0, 1, 6_000) } else { (1, 2, 20_000) };
    let mut rows: Vec<Row> = Vec::new();
    for order in 3..=8 {
        let train = generate(&SynthConfig::order_sweep(order, 64, nnz, 3));
        for strategy in [Strategy::Calculation, Strategy::Storage] {
            let mut cfg = TrainConfig::default();
            cfg.variant = Variant::Tc;
            cfg.strategy = strategy;
            let label = format!("n{order}/plus_tc_{strategy:?}").to_lowercase();
            rows.extend(bench_phases(&label, &train, cfg, warmup, reps)?);
        }
    }
    report("Fig. 5 — calculation vs storage across order (TC)", &rows);
    Ok(())
}
