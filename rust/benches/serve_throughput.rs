//! Serving-path throughput and latency over the Netflix-like surrogate —
//! the production-side counterpart to the training benches.
//!
//! Rows (all `BENCH_JSON`-scraped, see BENCHMARKS.md):
//!
//! * `predict_1t` — single-thread point-prediction throughput straight
//!   through [`Engine::predict`] (no server), with per-query p50/p99
//!   latency extras.
//! * `server_tK` — end-to-end QPS through the batched threaded [`Server`]
//!   at K workers with K concurrent blocking clients (queue + batch +
//!   snapshot-read overhead included), plus p50/p99 call latency.
//! * `complete_cold` vs `complete_cached` — the serving analog of the
//!   paper's calc-vs-store knob: score every item of one user fiber via
//!   per-item full-chain predicts (cold — the exclusion product is
//!   effectively recomputed per candidate) vs one [`Engine::complete_mode`]
//!   sweep (the fiber invariant computed once, then one R-wide dot per
//!   candidate).  The `items_per_s` extras give the shared-invariant win.
//!
//! Run: `cargo bench --bench serve_throughput` (BENCH_QUICK=1 shrinks it).

use std::sync::Mutex;
use std::time::Instant;

use fasttucker::bench::{measure, percentile, report, Row};
use fasttucker::coordinator::{Backend, TrainConfig};
use fasttucker::serve::{Engine, Server};
use fasttucker::session::{NullObserver, Schedule, Session};
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (nnz, epochs, queries) = if quick {
        (30_000, 2, 2_000)
    } else {
        (120_000, 4, 20_000)
    };
    let train = generate(&SynthConfig::netflix_like(nnz, 7));
    let cfg = TrainConfig {
        backend: Backend::ParallelCpu,
        ..TrainConfig::default()
    };
    // train the serving model through a scheduled session (no held-out
    // split — the bench serves, it doesn't evaluate)
    let schedule = Schedule {
        epochs,
        eval_every: 0,
        test_frac: 0.0,
        ..Schedule::default()
    };
    let mut session = Session::with_owned_tensor(train, cfg, schedule)?;
    session.run(&mut NullObserver)?;
    let snap = session.snapshot();
    let dims = snap.dims().to_vec();
    let n = dims.len();

    // fixed query set, shared by every configuration
    let mut rng = Pcg32::new(13, 0xBE);
    let coords: Vec<u32> = (0..queries)
        .flat_map(|_| dims.iter().map(|&d| rng.gen_range(d)).collect::<Vec<u32>>())
        .collect();

    let mut rows: Vec<Row> = Vec::new();

    // --- single-thread engine throughput + latency ------------------------
    let engine = Engine::new(snap.clone());
    let mut lat: Vec<f64> = Vec::with_capacity(queries);
    let mut row = measure("predict_1t", 1, 5, || {
        lat.clear();
        let mut sink = 0f64;
        for q in coords.chunks_exact(n) {
            let t = Instant::now();
            sink += engine.predict(q) as f64;
            lat.push(t.elapsed().as_secs_f64());
        }
        sink
    });
    row.extra.push(("qps".into(), queries as f64 / row.median_s));
    row.extra.push(("p50_us".into(), percentile(&mut lat, 50.0) * 1e6));
    row.extra.push(("p99_us".into(), percentile(&mut lat, 99.0) * 1e6));
    rows.push(row);

    // --- threaded server QPS + call latency -------------------------------
    for workers in [1usize, 2, 4] {
        let server = Server::start(snap.clone(), workers, 32);
        let latencies = Mutex::new(Vec::with_capacity(queries));
        let label = format!("server_t{workers}");
        let mut row = measure(&label, 1, 3, || {
            latencies.lock().unwrap().clear();
            std::thread::scope(|scope| {
                for c in 0..workers {
                    let handle = server.handle();
                    let latencies = &latencies;
                    let coords = &coords;
                    scope.spawn(move || {
                        let mut local = Vec::with_capacity(queries / workers + 1);
                        for q in coords.chunks_exact(n).skip(c).step_by(workers) {
                            let t = Instant::now();
                            handle.predict(q.to_vec()).expect("predict");
                            local.push(t.elapsed().as_secs_f64());
                        }
                        latencies.lock().unwrap().extend(local);
                    });
                }
            });
            0.0
        });
        let stats = server.shutdown();
        let mut lat = latencies.into_inner().unwrap();
        row.extra.push(("qps".into(), queries as f64 / row.median_s));
        row.extra.push(("p50_us".into(), percentile(&mut lat, 50.0) * 1e6));
        row.extra.push(("p99_us".into(), percentile(&mut lat, 99.0) * 1e6));
        row.extra.push((
            "mean_batch".into(),
            stats.served as f64 / stats.batches.max(1) as f64,
        ));
        rows.push(row);
    }

    // --- cold vs fiber-cached mode completion -----------------------------
    // one user fiber, every item scored (the per-user recommender sweep)
    let items = dims[1] as usize;
    let user_coords = [coords[0], 0, coords[2]];
    let mut engine = Engine::new(snap.clone());
    let mut row = measure("complete_cold", 1, 5, || {
        let mut sink = 0f64;
        let mut q = user_coords;
        for item in 0..items as u32 {
            q[1] = item;
            sink += engine.predict(&q) as f64;
        }
        sink
    });
    row.extra.push(("items_per_s".into(), items as f64 / row.median_s));
    rows.push(row);

    let mut scores = Vec::with_capacity(items);
    let mut row = measure("complete_cached", 1, 5, || {
        scores.clear();
        engine.complete_mode(&user_coords, 1, &mut scores);
        scores.iter().map(|&s| s as f64).sum()
    });
    row.extra.push(("items_per_s".into(), items as f64 / row.median_s));
    let cold = rows
        .iter()
        .find(|r| r.label == "complete_cold")
        .map(|r| r.median_s)
        .unwrap_or(f64::NAN);
    row.extra.push(("speedup_vs_cold".into(), cold / row.median_s));
    rows.push(row);

    report(
        &format!("Serve throughput — netflix-like, {nnz} nnz, {queries} queries"),
        &rows,
    );
    Ok(())
}
