//! Table 10 — FastTuckerPlus runtime under (R, J) in {16,32}^2 on the
//! real-dataset surrogates.
//!
//! Paper shape: doubling J or R increases runtime by LESS than 2x (the
//! batch's fixed overheads and the MXU's tile efficiency amortize), and
//! J doubles the factor-phase cost more than R does (R leaves the A_Ψ
//! traffic unchanged).

use fasttucker::bench::{bench_phases, report, Row};
use fasttucker::coordinator::TrainConfig;
use fasttucker::synth::{generate, SynthConfig};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (warmup, reps, nnz) = if quick { (0, 1, 20_000) } else { (1, 3, 80_000) };
    for (ds, cfg_t) in [
        ("netflix-like", SynthConfig::netflix_like(nnz, 7)),
        ("yahoo-like", SynthConfig::yahoo_like(nnz, 8)),
    ] {
        let train = generate(&cfg_t);
        let mut rows: Vec<Row> = Vec::new();
        let mut base: Option<(f64, f64)> = None;
        for (j, r) in [(16, 16), (16, 32), (32, 16), (32, 32)] {
            let mut cfg = TrainConfig::default();
            cfg.j = j;
            cfg.r = r;
            let label = format!("j{j}_r{r}");
            let mut rs = bench_phases(&label, &train, cfg, warmup, reps)?;
            match base {
                None => base = Some((rs[0].median_s, rs[1].median_s)),
                Some((bf, bc)) => {
                    let (f, c) = (rs[0].median_s / bf, rs[1].median_s / bc);
                    rs[0].extra.push(("vs_16_16".into(), f));
                    rs[1].extra.push(("vs_16_16".into(), c));
                }
            }
            rows.extend(rs);
        }
        report(&format!("Table 10 — runtime vs (J,R) ({ds})"), &rows);
    }
    Ok(())
}
