"""AOT lowering: jax -> HLO text + manifest.json.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the rust `xla` 0.1.6 crate) rejects; the text parser reassigns
ids and round-trips cleanly.

Run as ``python -m compile.aot --out ../artifacts`` (what `make artifacts`
does).  Lowering is incremental: an artifact is re-lowered only when missing,
so `make artifacts` is cheap when inputs are unchanged (the Makefile dep on
the kernel sources forces a rebuild when they change).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(kernel: str, n: int, j: int, r: int, s: int, out_dir: str) -> dict:
    name = model.artifact_name(kernel, n, j, r, s)
    path = os.path.join(out_dir, name + ".hlo.txt")
    entry = {
        "name": name,
        "kernel": kernel,
        "n": n, "j": j, "r": r, "s": s,
        "file": os.path.basename(path),
    }
    fn, args = model.build(kernel, n, j, r, s)
    entry["inputs"] = [list(a.shape) for a in args]
    if not os.path.exists(path):
        lowered = fn.lower(*args)
        text = to_hlo_text(lowered)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(text)
        os.replace(tmp, path)
        print(f"  lowered {name} ({len(text)//1024} KiB)")
    return entry


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", default=None,
                    help="comma-separated kernel-name prefixes to lower")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    prefixes = args.only.split(",") if args.only else None

    entries = []
    for cfg in model.artifact_configs():
        kernel = cfg[0]
        if prefixes and not any(kernel.startswith(p) for p in prefixes):
            continue
        entries.append(lower_one(*cfg, out_dir=args.out))

    manifest = {
        "format": 1,
        "dtype": "f32",
        "artifacts": entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(entries)} artifacts in {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
