"""L2: the FastTucker-family compute graphs, assembled from the L1 kernels.

Each entry in :data:`KERNELS` is a build-time computation the Rust L3
coordinator executes via PJRT.  ``build(name, n, j, r, s)`` returns the jax
callable plus its example arguments; ``aot.py`` lowers these to HLO text.

Shape conventions (all f32):
    a   [N, S, J]   gathered factor rows (mode-major; target mode rotated to
                    index 0 for the per-mode baseline kernels)
    b   [N, J, R]   core matrices (rotated likewise)
    c   [N, S, R]   precomputed projection rows (storage scheme)
    x   [S]         sample values
    hp  [2]         (learning rate, regularization lambda)
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import kernels as K

F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


@dataclass(frozen=True)
class KernelDef:
    """A lowerable computation: `args(n,j,r,s)` gives the example shapes."""

    fn: object
    args: object  # callable (n, j, r, s) -> tuple of ShapeDtypeStructs


def _plus_factor(variant):
    return KernelDef(
        fn=functools.partial(K.plus_factor, variant=variant),
        args=lambda n, j, r, s: (_spec(n, s, j), _spec(n, j, r), _spec(s), _spec(2)),
    )


def _plus_core(variant):
    return KernelDef(
        fn=functools.partial(K.plus_core, variant=variant),
        args=lambda n, j, r, s: (_spec(n, s, j), _spec(n, j, r), _spec(s)),
    )


def _plus_factor_storage(variant):
    return KernelDef(
        fn=functools.partial(K.plus_factor_storage, variant=variant),
        args=lambda n, j, r, s: (
            _spec(n, s, j), _spec(n, s, r), _spec(n, j, r), _spec(s), _spec(2)),
    )


def _plus_core_storage(variant):
    return KernelDef(
        fn=functools.partial(K.plus_core_storage, variant=variant),
        args=lambda n, j, r, s: (_spec(n, s, j), _spec(n, s, r), _spec(s)),
    )


def _ft_factor(variant):
    return KernelDef(
        fn=functools.partial(K.fasttucker_factor_mode, variant=variant),
        args=lambda n, j, r, s: (_spec(n, s, j), _spec(n, j, r), _spec(s), _spec(2)),
    )


def _ft_core(variant):
    return KernelDef(
        fn=functools.partial(K.fasttucker_core_mode, variant=variant),
        args=lambda n, j, r, s: (_spec(n, s, j), _spec(n, j, r), _spec(s)),
    )


def _fst_factor(variant):
    return KernelDef(
        fn=functools.partial(K.fastertucker_factor_mode, variant=variant),
        args=lambda n, j, r, s: (
            _spec(s, j), _spec(n - 1, s, r), _spec(j, r), _spec(s), _spec(2)),
    )


def _fst_core(variant):
    return KernelDef(
        fn=functools.partial(K.fastertucker_core_mode, variant=variant),
        args=lambda n, j, r, s: (
            _spec(s, j), _spec(n - 1, s, r), _spec(j, r), _spec(s)),
    )


def _predict(variant):
    return KernelDef(
        fn=functools.partial(K.predict, variant=variant),
        args=lambda n, j, r, s: (_spec(n, s, j), _spec(n, j, r)),
    )


def _compute_c(variant):
    # `s` doubles as the row-chunk size; `n` is unused.
    return KernelDef(
        fn=functools.partial(K.compute_c, variant=variant),
        args=lambda n, j, r, s: (_spec(s, j), _spec(j, r)),
    )


KERNELS: dict[str, KernelDef] = {}
for v in ("tc", "cc"):
    KERNELS[f"plus_factor_{v}"] = _plus_factor(v)
    KERNELS[f"plus_core_{v}"] = _plus_core(v)
    KERNELS[f"plus_factor_storage_{v}"] = _plus_factor_storage(v)
    KERNELS[f"plus_core_storage_{v}"] = _plus_core_storage(v)
    KERNELS[f"fasttucker_factor_{v}"] = _ft_factor(v)
    KERNELS[f"fasttucker_core_{v}"] = _ft_core(v)
    KERNELS[f"fastertucker_factor_{v}"] = _fst_factor(v)
    KERNELS[f"fastertucker_core_{v}"] = _fst_core(v)
KERNELS["predict"] = _predict("tc")
KERNELS["compute_c"] = _compute_c("tc")


def artifact_name(kernel: str, n: int, j: int, r: int, s: int) -> str:
    return f"{kernel}_n{n}_j{j}_r{r}_s{s}"


def build(kernel: str, n: int, j: int, r: int, s: int):
    """Return (jitted_fn, example_args) for one artifact config."""
    kd = KERNELS[kernel]
    # Wrap so outputs are a flat tuple (stable interchange with rust).
    def wrapped(*args):
        out = kd.fn(*args)
        return tuple(out) if isinstance(out, (tuple, list)) else (out,)
    return jax.jit(wrapped), kd.args(n, j, r, s)


# ---------------------------------------------------------------------------
# The artifact set `make artifacts` produces.  Kept deliberately explicit so
# the manifest doubles as documentation of what the benches rely on.
# ---------------------------------------------------------------------------

# Block size S: larger blocks amortize the per-execute PJRT dispatch cost
# (the L3 §Perf pass measured ~0.5 ms fixed overhead per call on the CPU
# client; S=4096 cut plus-phase wall time ~3x vs S=512).  The VMEM tile per
# grid step stays 128 samples regardless.
DEFAULT_S = 4096
SWEEP_S = 2048


def artifact_configs():
    """Yield (kernel, n, j, r, s) for every artifact we ship."""
    # Base config: 3-order (Netflix/Yahoo-like), J=R=16 as in the paper §5.1.
    for kernel in KERNELS:
        if kernel == "compute_c":
            yield (kernel, 3, 16, 16, DEFAULT_S)
        else:
            yield (kernel, 3, 16, 16, DEFAULT_S)
    # Order sweep 4..8 (Fig. 2/3/4/5 analogs) for every algorithm, tc + cc.
    for n in range(4, 9):
        for kernel in (
            "plus_factor_tc", "plus_core_tc",
            "plus_factor_cc", "plus_core_cc",
            "plus_factor_storage_tc", "plus_core_storage_tc",
            "fasttucker_factor_tc", "fasttucker_core_tc",
            "fasttucker_factor_cc", "fasttucker_core_cc",
            "fastertucker_factor_tc", "fastertucker_core_tc",
            "fastertucker_factor_cc", "fastertucker_core_cc",
            "predict",
        ):
            yield (kernel, n, 16, 16, SWEEP_S)
    # Parameter sweep (Table 10): (J,R) in {16,32}^2 minus the base point.
    for (j, r) in ((16, 32), (32, 16), (32, 32)):
        for kernel in ("plus_factor_tc", "plus_core_tc", "predict", "compute_c"):
            yield (kernel, 3, j, r, DEFAULT_S)
