"""L1 Pallas kernels for the FastTucker baseline (Algorithm 1, Eqs. 16-17).

Alg. 1 updates ONE mode per pass (the convex per-mode subproblem).  The host
(L3) rotates the mode order so the target mode is always index 0, re-gathers
`a` and `b` for every mode, and invokes these kernels N times per block —
reproducing FastTucker's N-fold memory traffic and recompute cost
((MN-M+R+1)*sum J_n reads, MR((N-1)*sum J_n + N(N-2)) multiplies, Table 4).
Keeping the per-mode pass a *separate executable invocation* is essential:
it prevents XLA from CSE-ing the recomputation the way the real algorithm
cannot, so the cost structure of the baseline is preserved.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import hadamard_chain, matmul, matmul_nt, matmul_t, tile


def _factor_mode_kernel(a_ref, b_ref, x_ref, hp_ref, out_ref, xhat_ref, *,
                        n_modes: int, variant: str):
    a = a_ref[...]          # [N, TS, J] with the target mode rotated to 0
    b = b_ref[...]
    x = x_ref[...]
    lr, lam = hp_ref[0], hp_ref[1]
    # Recompute every C^(k) from scratch (no sharing across modes — each mode
    # pass is its own executable call, see module docstring).
    cs = [matmul(a[k], b[k], variant) for k in range(n_modes)]
    d, full = hadamard_chain(cs)
    xhat = full.sum(axis=-1)
    err = x - xhat
    g = err[:, None] * matmul_nt(d[0], b[0], variant) - lam * a[0]
    out_ref[...] = a[0] + lr * g
    xhat_ref[...] = xhat


def fasttucker_factor_mode(a, b, x, hp, *, variant: str = "tc"):
    """Eq.-16 update of the rotated-to-front mode.  a:[N,S,J], b:[N,J,R],
    x:[S], hp:[2].  Returns (a0_new [S,J], x_hat [S])."""
    n_modes, s, j = a.shape
    r = b.shape[2]
    ts = tile(s)
    return pl.pallas_call(
        functools.partial(_factor_mode_kernel, n_modes=n_modes, variant=variant),
        grid=(s // ts,),
        in_specs=[
            pl.BlockSpec((n_modes, ts, j), lambda i: (0, i, 0)),
            pl.BlockSpec((n_modes, j, r), lambda i: (0, 0, 0)),
            pl.BlockSpec((ts,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((ts, j), lambda i: (i, 0)),
            pl.BlockSpec((ts,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, j), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
        ],
        interpret=True,
    )(a, b, x, hp)


def _core_mode_kernel(a_ref, b_ref, x_ref, grad_ref, xhat_ref, *,
                      n_modes: int, variant: str):
    a = a_ref[...]
    b = b_ref[...]
    x = x_ref[...]
    cs = [matmul(a[k], b[k], variant) for k in range(n_modes)]
    d, full = hadamard_chain(cs)
    xhat = full.sum(axis=-1)
    err = x - xhat

    @pl.when(pl.program_id(0) == 0)
    def _init():
        grad_ref[...] = jnp.zeros_like(grad_ref)

    e = err[:, None] * a[0]
    grad_ref[...] += matmul_t(e, d[0], variant)
    xhat_ref[...] = xhat


def fasttucker_core_mode(a, b, x, *, variant: str = "tc"):
    """Eq.-17 raw gradient for the rotated-to-front mode's core matrix.
    Returns (grad [J,R], x_hat [S])."""
    n_modes, s, j = a.shape
    r = b.shape[2]
    ts = tile(s)
    return pl.pallas_call(
        functools.partial(_core_mode_kernel, n_modes=n_modes, variant=variant),
        grid=(s // ts,),
        in_specs=[
            pl.BlockSpec((n_modes, ts, j), lambda i: (0, i, 0)),
            pl.BlockSpec((n_modes, j, r), lambda i: (0, 0, 0)),
            pl.BlockSpec((ts,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((j, r), lambda i: (0, 0)),
            pl.BlockSpec((ts,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((j, r), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
        ],
        interpret=True,
    )(a, b, x)


