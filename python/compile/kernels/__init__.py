"""L1 Pallas kernels for the FastTucker family (see DESIGN.md)."""

from .fasttuckerplus import (  # noqa: F401
    compute_c,
    plus_core,
    plus_core_storage,
    plus_factor,
    plus_factor_storage,
    predict,
)
from .fasttucker import (  # noqa: F401
    fasttucker_core_mode,
    fasttucker_factor_mode,
)
from .fastertucker import (  # noqa: F401
    fastertucker_core_mode,
    fastertucker_factor_mode,
)
