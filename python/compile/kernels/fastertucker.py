"""L1 Pallas kernels for the FasterTucker baseline (Algorithm 2, Eqs. 18-19).

FasterTucker avoids recomputing C^(k) = A^(k) B^(k) for the non-target modes
by *reading* precomputed rows c^(k)_{i_k,:} from memory (the storage scheme
the paper's §5.6 contrasts with Plus's recompute-on-tensor-cores).  Only the
target mode's own C rows are recomputed, because its factor rows change.

As with the FastTucker kernels, the host rotates the target mode to index 0
and calls once per mode, preserving the baseline's traffic pattern:
(M+R)*sum J_n + N(N-1)R parameters read per batch (Table 4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import hadamard_chain, matmul, matmul_nt, matmul_t, tile




def _factor_mode_kernel(a0_ref, co_ref, b0_ref, x_ref, hp_ref,
                        out_ref, c0_ref, xhat_ref, *, n_modes, variant):
    a0 = a0_ref[...]        # [TS, J]   target-mode factor rows
    co = co_ref[...]        # [N-1, TS, R] precomputed rows of the other modes
    b0 = b0_ref[...]        # [J, R]
    x = x_ref[...]
    lr, lam = hp_ref[0], hp_ref[1]
    c0 = matmul(a0, b0, variant)                       # recompute own C rows
    cs = [c0] + [co[k] for k in range(n_modes - 1)]
    d, full = hadamard_chain(cs)
    xhat = full.sum(axis=-1)
    err = x - xhat
    g = err[:, None] * matmul_nt(d[0], b0, variant) - lam * a0
    a0_new = a0 + lr * g
    out_ref[...] = a0_new
    # Refresh the stored C rows for the updated mode (Alg. 2 line 13).
    c0_ref[...] = matmul(a0_new, b0, variant)
    xhat_ref[...] = xhat


def fastertucker_factor_mode(a0, c_others, b0, x, hp, *, variant: str = "tc"):
    """Eq.-18 update.  a0:[S,J], c_others:[N-1,S,R], b0:[J,R], x:[S], hp:[2].
    Returns (a0_new [S,J], c0_new [S,R], x_hat [S])."""
    s, j = a0.shape
    nm1, _, r = c_others.shape
    n_modes = nm1 + 1
    ts = tile(s)
    return pl.pallas_call(
        functools.partial(_factor_mode_kernel, n_modes=n_modes, variant=variant),
        grid=(s // ts,),
        in_specs=[
            pl.BlockSpec((ts, j), lambda i: (i, 0)),
            pl.BlockSpec((nm1, ts, r), lambda i: (0, i, 0)),
            pl.BlockSpec((j, r), lambda i: (0, 0)),
            pl.BlockSpec((ts,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((ts, j), lambda i: (i, 0)),
            pl.BlockSpec((ts, r), lambda i: (i, 0)),
            pl.BlockSpec((ts,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, j), jnp.float32),
            jax.ShapeDtypeStruct((s, r), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
        ],
        interpret=True,
    )(a0, c_others, b0, x, hp)


def _core_mode_kernel(a0_ref, co_ref, b0_ref, x_ref, grad_ref, xhat_ref, *,
                      n_modes, variant):
    a0 = a0_ref[...]
    co = co_ref[...]
    b0 = b0_ref[...]
    x = x_ref[...]
    c0 = matmul(a0, b0, variant)
    cs = [c0] + [co[k] for k in range(n_modes - 1)]
    d, full = hadamard_chain(cs)
    xhat = full.sum(axis=-1)
    err = x - xhat

    @pl.when(pl.program_id(0) == 0)
    def _init():
        grad_ref[...] = jnp.zeros_like(grad_ref)

    e = err[:, None] * a0
    grad_ref[...] += matmul_t(e, d[0], variant)
    xhat_ref[...] = xhat


def fastertucker_core_mode(a0, c_others, b0, x, *, variant: str = "tc"):
    """Eq.-19 raw gradient.  Returns (grad [J,R], x_hat [S])."""
    s, j = a0.shape
    nm1, _, r = c_others.shape
    n_modes = nm1 + 1
    ts = tile(s)
    return pl.pallas_call(
        functools.partial(_core_mode_kernel, n_modes=n_modes, variant=variant),
        grid=(s // ts,),
        in_specs=[
            pl.BlockSpec((ts, j), lambda i: (i, 0)),
            pl.BlockSpec((nm1, ts, r), lambda i: (0, i, 0)),
            pl.BlockSpec((j, r), lambda i: (0, 0)),
            pl.BlockSpec((ts,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((j, r), lambda i: (0, 0)),
            pl.BlockSpec((ts,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((j, r), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
        ],
        interpret=True,
    )(a0, c_others, b0, x)
