"""Shared building blocks for the L1 Pallas kernels.

Two contraction variants mirror the paper's CUDA-core vs Tensor-core split,
re-thought for TPU (see DESIGN.md §Hardware-Adaptation):

* ``tc``  — ``jnp.dot`` with ``preferred_element_type=float32``: on a real TPU
  this is the MXU (systolic array) path, the analog of WMMA 16x16x16 tiles.
* ``cc``  — broadcast-multiply + sum reduction: the VPU (vector unit) path,
  the analog of doing the same contraction on CUDA cores with warp shuffles.

Both produce identical numerics in f32; only the op structure differs, which
is exactly the contrast the paper's Table 8 / Fig. 4 measure.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Tile sizes mirror the paper's WMMA geometry: M = 16 samples per "warp",
# J_n and R multiples of 16.  TILE_S is the batch-axis block held in VMEM per
# grid step (the warp-register analog).
WMMA = 16


def tile(s: int) -> int:
    """Batch-axis tile for a block of S samples.

    Two regimes (DESIGN.md §Perf, L1):
    * small S (tests, toy runs): the largest power-of-two divisor up to 128
      — exercises the multi-step grid/BlockSpec pipeline, which is the real
      TPU schedule (128-sample VMEM tiles streaming HBM->VMEM).
    * large S (production artifacts, S >= 1024): one grid step covering the
      whole block.  Under interpret=True a multi-step grid lowers to an XLA
      while-loop that re-materializes the full output via dynamic-update-
      slice every step — O(S^2/TILE) copies; measured 3.2 ms vs 0.8 ms per
      4096-sample block.  On CPU there is no VMEM to respect, so grid=1 is
      the faithful *and* fast lowering; the TPU BlockSpec schedule is still
      validated by the small-S configs in pytest.
    """
    if s >= 1024:
        return s
    t = 128
    while t > 1 and s % t != 0:
        t //= 2
    return t


def matmul(a, b, variant: str):
    """``a @ b`` with the given variant.  a: [m,k], b: [k,n] -> [m,n]."""
    if variant == "tc":
        return jnp.dot(a, b, preferred_element_type=jnp.float32)
    if variant == "cc":
        # VPU-shaped: explicit broadcast + reduce, no dot/MXU op.
        return (a[:, :, None] * b[None, :, :]).sum(axis=1)
    raise ValueError(f"unknown variant {variant!r}")


def matmul_t(a, b, variant: str):
    """``a.T @ b`` without materializing the transpose.  a: [s,m], b: [s,n]
    -> [m,n].  The explicit-transpose form (`jnp.dot(a.T, b)`) forces a
    layout change per grid step on the CPU backend (~5x slower measured);
    `dot_general` contracting over axis 0 of both operands avoids it and on
    TPU maps to the same MXU pass."""
    if variant == "tc":
        return jax.lax.dot_general(
            a, b, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    if variant == "cc":
        return (a[:, :, None] * b[:, None, :]).sum(axis=0)
    raise ValueError(f"unknown variant {variant!r}")


def matmul_nt(a, b, variant: str):
    """``a @ b.T`` without materializing the transpose.  a: [m,k], b: [n,k]
    -> [m,n].  Same rationale as :func:`matmul_t`."""
    if variant == "tc":
        return jax.lax.dot_general(
            a, b, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    if variant == "cc":
        return (a[:, None, :] * b[None, :, :]).sum(axis=2)
    raise ValueError(f"unknown variant {variant!r}")


def hadamard_chain(cs):
    """Given the list C^(n) [S,R] for n=0..N-1, return (D, full) where
    D[n] = prod_{k != n} C^(k) and full = prod_k C^(k).

    Uses the prefix/suffix-product trick: 2(N-1) Hadamard products per chain
    instead of the naive N(N-1) (division-free, stable at zeros).  This is the
    paper's "shared, reusable intermediate" insight (Table 4, Plus column).
    """
    n = len(cs)
    pre = [None] * (n + 1)
    suf = [None] * (n + 1)
    pre[0] = jnp.ones_like(cs[0])
    suf[n] = jnp.ones_like(cs[0])
    for i in range(n):
        pre[i + 1] = pre[i] * cs[i]
    for i in range(n - 1, -1, -1):
        suf[i] = suf[i + 1] * cs[i]
    d = [pre[i] * suf[i + 1] for i in range(n)]
    return d, pre[n]


def predict_from_c(cs):
    """x_hat [S] from the per-mode projection rows C^(n) [S,R]."""
    _, full = hadamard_chain(cs)
    return full.sum(axis=-1)
