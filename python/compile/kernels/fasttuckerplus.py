"""L1 Pallas kernels for FastTuckerPlus (Algorithm 3, Eqs. 14-15).

Each kernel processes a block of S samples in grid steps of TILE_S (the
"warp processes one Psi" analog).  All contractions are WMMA/MXU-shaped:
[TILE_S x J] . [J x R] with J, R multiples of 16.

Kernels (all interpret=True -> plain HLO, runnable on the CPU PJRT client):

* ``plus_factor``          — Eq. 14: update ALL factor rows of the batch.
* ``plus_core``            — Eq. 15: accumulate core-matrix gradients.
* ``plus_factor_storage``  — Table 9 "Storage" scheme: D from precomputed C rows.
* ``plus_core_storage``    — same for the core phase.
* ``predict``              — x_hat only (eval path).
* ``compute_c``            — C^(n) = A^(n) B^(n) chunk (storage-scheme precompute).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import hadamard_chain, matmul, matmul_nt, matmul_t, tile




# ---------------------------------------------------------------------------
# plus_factor: a_new[n] = a[n] + lr*(err * (D[n] @ B[n]^T) - lam*a[n])
# ---------------------------------------------------------------------------

def _plus_factor_kernel(a_ref, b_ref, x_ref, hp_ref, out_ref, xhat_ref, *,
                        n_modes: int, variant: str):
    a = a_ref[...]          # [N, TS, J]
    b = b_ref[...]          # [N, J, R]
    x = x_ref[...]          # [TS]
    lr, lam = hp_ref[0], hp_ref[1]
    cs = [matmul(a[n], b[n], variant) for n in range(n_modes)]   # C^(n) [TS,R]
    d, full = hadamard_chain(cs)
    xhat = full.sum(axis=-1)
    err = x - xhat          # [TS]
    for n in range(n_modes):
        g = err[:, None] * matmul_nt(d[n], b[n], variant) - lam * a[n]
        out_ref[n, :, :] = a[n] + lr * g
    xhat_ref[...] = xhat


def plus_factor(a, b, x, hp, *, variant: str = "tc"):
    """Batched Eq.-14 step.  a:[N,S,J] gathered rows, b:[N,J,R], x:[S],
    hp:[2] = (lr, lam).  Returns (a_new [N,S,J], x_hat [S])."""
    n_modes, s, j = a.shape
    r = b.shape[2]
    ts = tile(s)
    grid = (s // ts,)
    return pl.pallas_call(
        functools.partial(_plus_factor_kernel, n_modes=n_modes, variant=variant),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_modes, ts, j), lambda i: (0, i, 0)),
            pl.BlockSpec((n_modes, j, r), lambda i: (0, 0, 0)),
            pl.BlockSpec((ts,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((n_modes, ts, j), lambda i: (0, i, 0)),
            pl.BlockSpec((ts,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_modes, s, j), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
        ],
        interpret=True,
    )(a, b, x, hp)


# ---------------------------------------------------------------------------
# plus_core: grad[n] = sum_s err_s * a_s^(n)T d_s^(n)  (Eq. 15, raw gradient;
# the L3 coordinator applies  B += lr*(grad/S - lam*B)  once per block, the
# analog of the paper's register-accumulate + atomicAdd-at-the-end).
# ---------------------------------------------------------------------------

def _plus_core_kernel(a_ref, b_ref, x_ref, grad_ref, xhat_ref, *,
                      n_modes: int, variant: str):
    a = a_ref[...]
    b = b_ref[...]
    x = x_ref[...]
    cs = [matmul(a[n], b[n], variant) for n in range(n_modes)]
    d, full = hadamard_chain(cs)
    xhat = full.sum(axis=-1)
    err = x - xhat

    @pl.when(pl.program_id(0) == 0)
    def _init():
        grad_ref[...] = jnp.zeros_like(grad_ref)

    for n in range(n_modes):
        e = err[:, None] * a[n]                       # E^(n) [TS,J]
        grad_ref[n, :, :] += matmul_t(e, d[n], variant)
    xhat_ref[...] = xhat


def plus_core(a, b, x, *, variant: str = "tc"):
    """Batched Eq.-15 gradient.  Returns (grad [N,J,R], x_hat [S])."""
    n_modes, s, j = a.shape
    r = b.shape[2]
    ts = tile(s)
    return pl.pallas_call(
        functools.partial(_plus_core_kernel, n_modes=n_modes, variant=variant),
        grid=(s // ts,),
        in_specs=[
            pl.BlockSpec((n_modes, ts, j), lambda i: (0, i, 0)),
            pl.BlockSpec((n_modes, j, r), lambda i: (0, 0, 0)),
            pl.BlockSpec((ts,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((n_modes, j, r), lambda i: (0, 0, 0)),
            pl.BlockSpec((ts,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_modes, j, r), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
        ],
        interpret=True,
    )(a, b, x)


# ---------------------------------------------------------------------------
# Storage-scheme variants (Table 9 / Fig. 5): C rows are *read* (inputs
# gathered by L3 from a precomputed C^(n) = A^(n) B^(n)) instead of recomputed
# on the matrix unit.  This trades N matmuls for N*[S,R] of extra traffic —
# exactly the trade §5.6 of the paper measures.
# ---------------------------------------------------------------------------

def _plus_factor_storage_kernel(a_ref, c_ref, b_ref, x_ref, hp_ref,
                                out_ref, xhat_ref, *, n_modes, variant):
    a = a_ref[...]
    c = c_ref[...]          # [N, TS, R] precomputed rows
    b = b_ref[...]
    x = x_ref[...]
    lr, lam = hp_ref[0], hp_ref[1]
    d, full = hadamard_chain([c[n] for n in range(n_modes)])
    xhat = full.sum(axis=-1)
    err = x - xhat
    for n in range(n_modes):
        g = err[:, None] * matmul_nt(d[n], b[n], variant) - lam * a[n]
        out_ref[n, :, :] = a[n] + lr * g
    xhat_ref[...] = xhat


def plus_factor_storage(a, c, b, x, hp, *, variant: str = "tc"):
    n_modes, s, j = a.shape
    r = b.shape[2]
    ts = tile(s)
    return pl.pallas_call(
        functools.partial(_plus_factor_storage_kernel, n_modes=n_modes,
                          variant=variant),
        grid=(s // ts,),
        in_specs=[
            pl.BlockSpec((n_modes, ts, j), lambda i: (0, i, 0)),
            pl.BlockSpec((n_modes, ts, r), lambda i: (0, i, 0)),
            pl.BlockSpec((n_modes, j, r), lambda i: (0, 0, 0)),
            pl.BlockSpec((ts,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((n_modes, ts, j), lambda i: (0, i, 0)),
            pl.BlockSpec((ts,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_modes, s, j), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
        ],
        interpret=True,
    )(a, c, b, x, hp)


def _plus_core_storage_kernel(a_ref, c_ref, x_ref, grad_ref, xhat_ref, *,
                              n_modes, variant):
    a = a_ref[...]
    c = c_ref[...]
    x = x_ref[...]
    d, full = hadamard_chain([c[n] for n in range(n_modes)])
    xhat = full.sum(axis=-1)
    err = x - xhat

    @pl.when(pl.program_id(0) == 0)
    def _init():
        grad_ref[...] = jnp.zeros_like(grad_ref)

    for n in range(n_modes):
        e = err[:, None] * a[n]
        grad_ref[n, :, :] += matmul_t(e, d[n], variant)
    xhat_ref[...] = xhat


def plus_core_storage(a, c, x, *, variant: str = "tc"):
    n_modes, s, j = a.shape
    r = c.shape[2]
    ts = tile(s)
    return pl.pallas_call(
        functools.partial(_plus_core_storage_kernel, n_modes=n_modes,
                          variant=variant),
        grid=(s // ts,),
        in_specs=[
            pl.BlockSpec((n_modes, ts, j), lambda i: (0, i, 0)),
            pl.BlockSpec((n_modes, ts, r), lambda i: (0, i, 0)),
            pl.BlockSpec((ts,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((n_modes, j, r), lambda i: (0, 0, 0)),
            pl.BlockSpec((ts,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_modes, j, r), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
        ],
        interpret=True,
    )(a, c, x)


# ---------------------------------------------------------------------------
# predict + compute_c
# ---------------------------------------------------------------------------

def _predict_kernel(a_ref, b_ref, xhat_ref, *, n_modes, variant):
    a = a_ref[...]
    b = b_ref[...]
    cs = [matmul(a[n], b[n], variant) for n in range(n_modes)]
    _, full = hadamard_chain(cs)
    xhat_ref[...] = full.sum(axis=-1)


def predict(a, b, *, variant: str = "tc"):
    """x_hat [S] for gathered rows a:[N,S,J] and cores b:[N,J,R]."""
    n_modes, s, j = a.shape
    r = b.shape[2]
    ts = tile(s)
    return pl.pallas_call(
        functools.partial(_predict_kernel, n_modes=n_modes, variant=variant),
        grid=(s // ts,),
        in_specs=[
            pl.BlockSpec((n_modes, ts, j), lambda i: (0, i, 0)),
            pl.BlockSpec((n_modes, j, r), lambda i: (0, 0, 0)),
        ],
        out_specs=[pl.BlockSpec((ts,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((s,), jnp.float32)],
        interpret=True,
    )(a, b)


def _compute_c_kernel(a_ref, b_ref, c_ref, *, variant):
    c_ref[...] = matmul(a_ref[...], b_ref[...], variant)


def compute_c(a, b, *, variant: str = "tc"):
    """One chunk of the storage-scheme precompute: C = A_chunk @ B.
    a: [CHUNK, J], b: [J, R] -> [CHUNK, R]."""
    chunk, j = a.shape
    r = b.shape[1]
    ts = tile(chunk)
    return pl.pallas_call(
        functools.partial(_compute_c_kernel, variant=variant),
        grid=(chunk // ts,),
        in_specs=[
            pl.BlockSpec((ts, j), lambda i: (i, 0)),
            pl.BlockSpec((j, r), lambda i: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((ts, r), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((chunk, r), jnp.float32)],
        interpret=True,
    )(a, b)
