"""Pure-jnp oracles for every L1 kernel.

These are the correctness ground truth: straightforward einsum/broadcast
implementations of Eqs. 14-19 with no Pallas, no tiling, no variants.
pytest asserts each kernel (tc AND cc variants) against these to ~1e-5.
"""

from __future__ import annotations

import jax.numpy as jnp


def predict_ref(a, b):
    """x_hat [S].  a: [N,S,J], b: [N,J,R]."""
    c = jnp.einsum("nsj,njr->nsr", a, b)
    return jnp.prod(c, axis=0).sum(axis=-1)


def _c_d(a, b):
    c = jnp.einsum("nsj,njr->nsr", a, b)          # [N,S,R]
    full = jnp.prod(c, axis=0)                    # [S,R]
    n = a.shape[0]
    d = jnp.stack([jnp.prod(jnp.delete(c, k, axis=0), axis=0)
                   for k in range(n)])            # [N,S,R]
    return c, d, full


def plus_factor_ref(a, b, x, hp):
    """(a_new [N,S,J], x_hat [S]) — Eq. 14."""
    lr, lam = hp[0], hp[1]
    _, d, full = _c_d(a, b)
    xhat = full.sum(axis=-1)
    err = (x - xhat)[None, :, None]               # [1,S,1]
    db = jnp.einsum("nsr,njr->nsj", d, b)         # D^(n) B^(n)T
    a_new = a + lr * (err * db - lam * a)
    return a_new, xhat


def plus_core_ref(a, b, x):
    """(grad [N,J,R], x_hat [S]) — Eq. 15, raw gradient (no reg/lr)."""
    _, d, full = _c_d(a, b)
    xhat = full.sum(axis=-1)
    err = x - xhat
    e = err[None, :, None] * a                    # [N,S,J]
    grad = jnp.einsum("nsj,nsr->njr", e, d)
    return grad, xhat


def plus_factor_storage_ref(a, c, b, x, hp):
    lr, lam = hp[0], hp[1]
    n = a.shape[0]
    full = jnp.prod(c, axis=0)
    d = jnp.stack([jnp.prod(jnp.delete(c, k, axis=0), axis=0)
                   for k in range(n)])
    xhat = full.sum(axis=-1)
    err = (x - xhat)[None, :, None]
    db = jnp.einsum("nsr,njr->nsj", d, b)
    return a + lr * (err * db - lam * a), xhat


def plus_core_storage_ref(a, c, x):
    n = a.shape[0]
    full = jnp.prod(c, axis=0)
    d = jnp.stack([jnp.prod(jnp.delete(c, k, axis=0), axis=0)
                   for k in range(n)])
    xhat = full.sum(axis=-1)
    e = (x - xhat)[None, :, None] * a
    return jnp.einsum("nsj,nsr->njr", e, d), xhat


def fasttucker_factor_mode_ref(a, b, x, hp):
    """(a0_new [S,J], x_hat [S]) — Eq. 16 for the rotated-to-front mode."""
    lr, lam = hp[0], hp[1]
    _, d, full = _c_d(a, b)
    xhat = full.sum(axis=-1)
    err = (x - xhat)[:, None]
    g = err * (d[0] @ b[0].T) - lam * a[0]
    return a[0] + lr * g, xhat


def fasttucker_core_mode_ref(a, b, x):
    """(grad [J,R], x_hat [S]) — Eq. 17 raw gradient."""
    _, d, full = _c_d(a, b)
    xhat = full.sum(axis=-1)
    e = (x - xhat)[:, None] * a[0]
    return e.T @ d[0], xhat


def _faster_c_d(a0, c_others, b0):
    c0 = a0 @ b0                                  # [S,R]
    cs = jnp.concatenate([c0[None], c_others], axis=0)
    full = jnp.prod(cs, axis=0)
    d0 = jnp.prod(c_others, axis=0)               # exclude mode 0
    return c0, d0, full


def fastertucker_factor_mode_ref(a0, c_others, b0, x, hp):
    """(a0_new, c0_new, x_hat) — Eq. 18."""
    lr, lam = hp[0], hp[1]
    _, d0, full = _faster_c_d(a0, c_others, b0)
    xhat = full.sum(axis=-1)
    err = (x - xhat)[:, None]
    a0_new = a0 + lr * (err * (d0 @ b0.T) - lam * a0)
    return a0_new, a0_new @ b0, xhat


def fastertucker_core_mode_ref(a0, c_others, b0, x):
    """(grad [J,R], x_hat) — Eq. 19 raw gradient."""
    _, d0, full = _faster_c_d(a0, c_others, b0)
    xhat = full.sum(axis=-1)
    e = (x - xhat)[:, None] * a0
    return e.T @ d0, xhat


def compute_c_ref(a, b):
    return a @ b
