"""Kernel-vs-oracle correctness: every Pallas kernel (tc AND cc variants)
against the pure-jnp reference, across shapes.  This is the CORE correctness
signal for L1."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile import kernels as K

jax.config.update("jax_platform_name", "cpu")

RTOL, ATOL = 1e-4, 1e-4


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * 0.5)


def data(n=3, s=64, j=16, r=16, seed=0):
    rng = np.random.default_rng(seed)
    a = rand(rng, n, s, j)
    b = rand(rng, n, j, r)
    x = rand(rng, s)
    hp = jnp.asarray([0.01, 0.001], dtype=np.float32)
    return a, b, x, hp


SHAPES = [(3, 64, 16, 16), (4, 32, 16, 16), (3, 128, 32, 16), (5, 16, 16, 32)]
VARIANTS = ["tc", "cc"]


@pytest.mark.parametrize("n,s,j,r", SHAPES)
@pytest.mark.parametrize("variant", VARIANTS)
def test_plus_factor(n, s, j, r, variant):
    a, b, x, hp = data(n, s, j, r)
    a_new, xhat = K.plus_factor(a, b, x, hp, variant=variant)
    a_ref, xhat_ref = ref.plus_factor_ref(a, b, x, hp)
    np.testing.assert_allclose(xhat, xhat_ref, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(a_new, a_ref, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("n,s,j,r", SHAPES)
@pytest.mark.parametrize("variant", VARIANTS)
def test_plus_core(n, s, j, r, variant):
    a, b, x, _ = data(n, s, j, r)
    grad, xhat = K.plus_core(a, b, x, variant=variant)
    grad_ref, xhat_ref = ref.plus_core_ref(a, b, x)
    np.testing.assert_allclose(xhat, xhat_ref, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(grad, grad_ref, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n,s,j,r", SHAPES[:2])
@pytest.mark.parametrize("variant", VARIANTS)
def test_plus_factor_storage(n, s, j, r, variant):
    a, b, x, hp = data(n, s, j, r)
    c = jnp.einsum("nsj,njr->nsr", a, b)
    a_new, xhat = K.plus_factor_storage(a, c, b, x, hp, variant=variant)
    a_ref, xhat_ref = ref.plus_factor_storage_ref(a, c, b, x, hp)
    np.testing.assert_allclose(xhat, xhat_ref, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(a_new, a_ref, rtol=RTOL, atol=ATOL)
    # storage scheme with fresh C must agree with the calculation scheme
    a_calc, _ = K.plus_factor(a, b, x, hp, variant=variant)
    np.testing.assert_allclose(a_new, a_calc, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("n,s,j,r", SHAPES[:2])
@pytest.mark.parametrize("variant", VARIANTS)
def test_plus_core_storage(n, s, j, r, variant):
    a, b, x, _ = data(n, s, j, r)
    c = jnp.einsum("nsj,njr->nsr", a, b)
    grad, xhat = K.plus_core_storage(a, c, x, variant=variant)
    grad_ref, xhat_ref = ref.plus_core_storage_ref(a, c, x)
    np.testing.assert_allclose(xhat, xhat_ref, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(grad, grad_ref, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n,s,j,r", SHAPES)
@pytest.mark.parametrize("variant", VARIANTS)
def test_fasttucker_factor(n, s, j, r, variant):
    a, b, x, hp = data(n, s, j, r)
    a0, xhat = K.fasttucker_factor_mode(a, b, x, hp, variant=variant)
    a0_ref, xhat_ref = ref.fasttucker_factor_mode_ref(a, b, x, hp)
    np.testing.assert_allclose(xhat, xhat_ref, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(a0, a0_ref, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("n,s,j,r", SHAPES)
@pytest.mark.parametrize("variant", VARIANTS)
def test_fasttucker_core(n, s, j, r, variant):
    a, b, x, _ = data(n, s, j, r)
    grad, xhat = K.fasttucker_core_mode(a, b, x, variant=variant)
    grad_ref, xhat_ref = ref.fasttucker_core_mode_ref(a, b, x)
    np.testing.assert_allclose(xhat, xhat_ref, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(grad, grad_ref, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n,s,j,r", SHAPES)
@pytest.mark.parametrize("variant", VARIANTS)
def test_fastertucker_factor(n, s, j, r, variant):
    a, b, x, hp = data(n, s, j, r)
    c_others = jnp.einsum("nsj,njr->nsr", a[1:], b[1:])
    a0, c0, xhat = K.fastertucker_factor_mode(a[0], c_others, b[0], x, hp,
                                              variant=variant)
    a0_ref, c0_ref, xhat_ref = ref.fastertucker_factor_mode_ref(
        a[0], c_others, b[0], x, hp)
    np.testing.assert_allclose(xhat, xhat_ref, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(a0, a0_ref, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(c0, c0_ref, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("n,s,j,r", SHAPES)
@pytest.mark.parametrize("variant", VARIANTS)
def test_fastertucker_core(n, s, j, r, variant):
    a, b, x, _ = data(n, s, j, r)
    c_others = jnp.einsum("nsj,njr->nsr", a[1:], b[1:])
    grad, xhat = K.fastertucker_core_mode(a[0], c_others, b[0], x,
                                          variant=variant)
    grad_ref, xhat_ref = ref.fastertucker_core_mode_ref(a[0], c_others, b[0], x)
    np.testing.assert_allclose(xhat, xhat_ref, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(grad, grad_ref, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n,s,j,r", SHAPES)
def test_predict(n, s, j, r):
    a, b, _, _ = data(n, s, j, r)
    xhat = K.predict(a, b)[0]
    np.testing.assert_allclose(xhat, ref.predict_ref(a, b), rtol=RTOL, atol=ATOL)


def test_compute_c():
    a, b, _, _ = data(3, 64, 16, 16)
    c = K.compute_c(a[0], b[0])[0]
    np.testing.assert_allclose(c, ref.compute_c_ref(a[0], b[0]),
                               rtol=RTOL, atol=ATOL)


def test_tc_cc_agree():
    """The two variants are the SAME math (Table 8's contrast is structural)."""
    a, b, x, hp = data(3, 64, 16, 16)
    a_tc, _ = K.plus_factor(a, b, x, hp, variant="tc")
    a_cc, _ = K.plus_factor(a, b, x, hp, variant="cc")
    np.testing.assert_allclose(a_tc, a_cc, rtol=1e-5, atol=1e-5)


def test_padding_rows_are_inert():
    """Zero-padded samples (a-rows = 0, x = 0) must not change anything:
    the L3 coordinator relies on this for partial blocks."""
    a, b, x, hp = data(3, 64, 16, 16)
    a = a.at[:, 32:, :].set(0.0)
    x = x.at[32:].set(0.0)
    a_new, xhat = K.plus_factor(a, b, x, hp, variant="tc")
    np.testing.assert_allclose(a_new[:, 32:, :], np.zeros_like(a_new[:, 32:, :]),
                               atol=1e-7)
    np.testing.assert_allclose(xhat[32:], np.zeros(32), atol=1e-7)
    grad_full, _ = K.plus_core(a, b, x)
    grad_half, _ = K.plus_core(a[:, :32, :], b, x[:32])
    np.testing.assert_allclose(grad_full, grad_half, rtol=1e-3, atol=1e-3)
