"""Hypothesis sweeps over kernel shapes/values: the L1 kernels must agree
with the pure-jnp oracle for arbitrary (N, S, J, R) in the supported range
and arbitrary finite inputs, in both variants."""

import numpy as np
import pytest

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref

SHAPE = st.tuples(
    st.integers(min_value=3, max_value=6),            # N
    st.sampled_from([16, 32, 48, 64]),                # S
    st.sampled_from([16, 32]),                        # J
    st.sampled_from([16, 32]),                        # R
)


def make(n, s, j, r, seed, scale):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((n, s, j), dtype=np.float32) * scale)
    b = jnp.asarray(rng.standard_normal((n, j, r), dtype=np.float32) * scale)
    x = jnp.asarray(rng.standard_normal(s, dtype=np.float32))
    hp = jnp.asarray([0.01, 0.001], dtype=np.float32)
    return a, b, x, hp


@settings(max_examples=12, deadline=None)
@given(shape=SHAPE, seed=st.integers(0, 2**31 - 1),
       scale=st.floats(min_value=0.01, max_value=1.0),
       variant=st.sampled_from(["tc", "cc"]))
def test_plus_factor_matches_ref(shape, seed, scale, variant):
    a, b, x, hp = make(*shape, seed, scale)
    a_new, xhat = K.plus_factor(a, b, x, hp, variant=variant)
    a_ref, xhat_ref = ref.plus_factor_ref(a, b, x, hp)
    # f32 accumulation-order noise grows with N and scale; 1% relative is
    # the right bound for order-6 chains of dots at scale ~1.
    np.testing.assert_allclose(xhat, xhat_ref, rtol=1e-2, atol=5e-3)
    np.testing.assert_allclose(a_new, a_ref, rtol=1e-2, atol=5e-3)


@settings(max_examples=12, deadline=None)
@given(shape=SHAPE, seed=st.integers(0, 2**31 - 1),
       variant=st.sampled_from(["tc", "cc"]))
def test_plus_core_matches_ref(shape, seed, variant):
    a, b, x, _ = make(*shape, seed, 0.4)
    grad, xhat = K.plus_core(a, b, x, variant=variant)
    grad_ref, xhat_ref = ref.plus_core_ref(a, b, x)
    np.testing.assert_allclose(xhat, xhat_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(grad, grad_ref, rtol=5e-3, atol=5e-3)


@settings(max_examples=10, deadline=None)
@given(shape=SHAPE, seed=st.integers(0, 2**31 - 1))
def test_predict_matches_ref(shape, seed):
    a, b, _, _ = make(*shape, seed, 0.5)
    xhat = K.predict(a, b)[0]
    np.testing.assert_allclose(xhat, ref.predict_ref(a, b),
                               rtol=1e-3, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(shape=SHAPE, seed=st.integers(0, 2**31 - 1))
def test_fastertucker_consistent_with_plus_forward(shape, seed):
    """Cross-algorithm invariant: with fresh (non-stale) C rows, the
    FasterTucker forward x_hat equals the Plus forward x_hat."""
    a, b, x, _ = make(*shape, seed, 0.4)
    c_others = jnp.einsum("nsj,njr->nsr", a[1:], b[1:])
    _, xhat_fst = K.fastertucker_core_mode(a[0], c_others, b[0], x)
    xhat_plus = ref.predict_ref(a, b)
    np.testing.assert_allclose(xhat_fst, x - (x - xhat_plus), rtol=1e-3,
                               atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       s=st.sampled_from([16, 48, 128]))
def test_factor_step_descends_loss(seed, s):
    """One Eq.-14 step with a small lr must not increase the squared error
    of the batch (descent property of the true gradient at small steps)."""
    a, b, x, _ = make(3, s, 16, 16, seed, 0.3)
    hp = jnp.asarray([1e-3, 0.0], dtype=np.float32)
    xhat0 = ref.predict_ref(a, b)
    a_new, _ = K.plus_factor(a, b, x, hp)
    xhat1 = ref.predict_ref(a_new, b)
    loss0 = float(((x - xhat0) ** 2).sum())
    loss1 = float(((x - xhat1) ** 2).sum())
    assert loss1 <= loss0 * 1.001, f"{loss0} -> {loss1}"


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_core_grad_is_true_gradient(seed):
    """The kernel's core gradient must equal the autodiff gradient of the
    0.5*sum((x-xhat)^2) loss wrt B (up to sign convention)."""
    import jax

    a, b, x, _ = make(3, 32, 16, 16, seed, 0.3)

    def loss(b_):
        xhat = ref.predict_ref(a, b_)
        return 0.5 * ((x - xhat) ** 2).sum()

    autograd = jax.grad(loss)(b)
    grad, _ = K.plus_core(a, b, x)
    # kernel returns ascent direction on err (descent on loss is -grad)
    np.testing.assert_allclose(grad, -autograd, rtol=5e-3, atol=5e-3)
