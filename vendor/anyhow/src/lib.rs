//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The offline container has no registry access, so this vendored shim
//! provides the subset of the `anyhow` 1.x API this workspace uses:
//!
//! * [`Error`] / [`Result`] with context chains,
//! * the [`Context`] extension trait for `Result` and `Option`,
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! Formatting matches anyhow's conventions where the workspace depends on
//! them: `{}` prints the outermost context, `{:#}` prints the whole chain
//! joined by `": "`.

use std::fmt;

/// An error with a chain of context messages (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes the blanket `From` below coherent (same trick as anyhow).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    /// Attach a context message, converting the error to [`Error`].
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Like [`Context::context`] but lazily evaluated.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "Condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Result::<(), _>::Err(io_err())
            .with_context(|| "outer".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: missing");
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.context("absent").unwrap_err();
        assert_eq!(format!("{e}"), "absent");
    }

    #[test]
    fn macros_work() {
        fn inner(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {}", flag);
            ensure!(flag);
            if !flag {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(inner(true).unwrap(), 7);
        assert_eq!(format!("{}", inner(false).unwrap_err()), "flag was false");
        let e = anyhow!("x = {}", 3);
        assert_eq!(format!("{e}"), "x = 3");
    }
}
