//! Compile-compatible stub of the `xla` PJRT binding surface used by the
//! `fasttucker` runtime layer.
//!
//! The offline container cannot build the native XLA extension, so this
//! crate provides the same types and signatures with constructors that
//! fail at runtime with a clear message.  The coordinator's HLO backend is
//! reached only when `artifacts/manifest.json` exists, and the HLO test
//! suite skips without it, so a clean checkout builds and tests green.
//!
//! Swapping in the real bindings is a one-line change in the workspace
//! `Cargo.toml` (point the `xla` dependency at the native crate); no
//! source in `rust/src/` mentions the stub.

use std::fmt;
use std::path::Path;

const UNAVAILABLE: &str = "XLA/PJRT native runtime is not available in this build \
     (offline `xla` stub); the HLO backend requires the real bindings — \
     use `--backend cpu` or `--backend parallel` instead";

/// Error type mirroring `xla::Error` closely enough for `?` conversions.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable() -> Error {
        Error(UNAVAILABLE.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Marker trait for element types accepted by buffer staging.
pub trait ElementType: Copy {}
impl ElementType for f32 {}

/// Marker trait for argument types accepted by [`PjRtLoadedExecutable::execute_b`].
pub trait BufferArg {}
impl BufferArg for PjRtBuffer {}

#[derive(Clone)]
pub struct PjRtClient;

pub struct PjRtDevice;

pub struct PjRtBuffer;

pub struct PjRtLoadedExecutable;

pub struct Literal;

pub struct HloModuleProto;

pub struct XlaComputation;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_buffer<T: ElementType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T: BufferArg>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("not available"));
    }
}
