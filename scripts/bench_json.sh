#!/usr/bin/env bash
# Run the paper-table bench suite and scrape each bench's BENCH_JSON lines
# into committed-friendly BENCH_<name>.json files:
#
#   scripts/bench_json.sh [out_dir]          # full runs (slow, real numbers)
#   BENCH_QUICK=1 scripts/bench_json.sh out  # CI-sized smoke numbers
#
# Each output file is one JSON object: {"bench": "<name>", "rows": [...]},
# where rows are the bench's Row::to_json() objects (median_s, mad_s, reps,
# plus extras such as speedup_vs_scalar_serial).  Regenerate on the target
# hardware before updating the BENCH_*.json files referenced by
# BENCHMARKS.md — never hand-edit the numbers.
set -euo pipefail

out_dir="${1:-.}"
mkdir -p "$out_dir"

benches=(parallel_scaling table8_tc_speedup serve_slo)

for b in "${benches[@]}"; do
    log="$(mktemp)"
    echo "== cargo bench --bench $b =="
    cargo bench --bench "$b" | tee "$log"
    rows="$(grep '^BENCH_JSON ' "$log" | sed 's/^BENCH_JSON //' | paste -sd, -)"
    rm -f "$log"
    if [ -z "$rows" ]; then
        echo "warning: $b produced no BENCH_JSON rows; skipping" >&2
        continue
    fi
    printf '{"bench":"%s","rows":[%s]}\n' "$b" "$rows" > "$out_dir/BENCH_$b.json"
    echo "wrote $out_dir/BENCH_$b.json"
done
