//! Train-and-serve concurrently: the tensor-completion service rebuilt on
//! the serving subsystem.  A [`Server`] opens on the epoch-0 snapshot and
//! keeps answering batched predict / top-K queries from concurrent client
//! threads while the trainer runs more epochs and hot-swaps fresh
//! snapshots in via `Trainer::publish` — in-flight queries always see one
//! consistent model, and clients observe the epoch tag advancing.
//!
//! Everything is in-process and offline (no sockets: a network front-end
//! would sit on top of the same [`ServerHandle`]).  CI runs this on every
//! PR.
//!
//! Run: `cargo run --release --example completion_server`

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use fasttucker::coordinator::{Backend, Trainer, TrainConfig};
use fasttucker::serve::Server;
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let tensor = generate(&SynthConfig::order_sweep(3, 256, 40_000, 5));
    let mut cfg = TrainConfig::default();
    if !cfg.hlo_available() {
        eprintln!("note: no artifacts; using --backend parallel");
        cfg.backend = Backend::ParallelCpu;
    }
    let mut trainer = Trainer::new(&tensor, cfg)?;
    let dims = tensor.dims.clone();

    let server = Server::start(trainer.snapshot(), 2, 16);
    println!(
        "serving order-{} model over dims {:?} (snapshot epoch {})",
        trainer.model.order(),
        dims,
        server.epoch()
    );

    // Client threads hammer the server while the main thread trains.
    let stop = AtomicBool::new(false);
    let max_epoch_seen = AtomicU64::new(0);
    let queries_ok = AtomicU64::new(0);
    std::thread::scope(|scope| -> anyhow::Result<()> {
        for c in 0..3u64 {
            let handle = server.handle();
            let stop = &stop;
            let max_epoch_seen = &max_epoch_seen;
            let queries_ok = &queries_ok;
            let dims = &dims;
            scope.spawn(move || {
                let mut rng = Pcg32::new(77, c);
                while !stop.load(Ordering::Relaxed) {
                    let coords: Vec<u32> = dims.iter().map(|&d| rng.gen_range(d)).collect();
                    let v = match c % 3 {
                        // mix predicts, top-K completions and epoch probes
                        0 => handle.predict(coords).expect("predict"),
                        1 => {
                            let top = handle.topk(coords, 2, 3).expect("topk");
                            top[0].score
                        }
                        _ => {
                            let e = handle.epoch().expect("epoch");
                            max_epoch_seen.fetch_max(e, Ordering::Relaxed);
                            e as f32
                        }
                    };
                    assert!(v.is_finite(), "query returned a non-finite value");
                    queries_ok.fetch_add(1, Ordering::Relaxed);
                }
            });
        }

        // Train 6 epochs, publishing after each — every publish is a
        // hot-swap under live traffic.  Always release the clients, even
        // if an epoch errors, so the scope can join.
        let trained = (|| -> anyhow::Result<()> {
            for epoch in 1..=6 {
                trainer.epoch(&tensor)?;
                trainer.publish(&server);
                println!(
                    "epoch {epoch}: published (server now at snapshot epoch {}, {} queries answered so far)",
                    server.epoch(),
                    queries_ok.load(Ordering::Relaxed)
                );
            }
            Ok(())
        })();
        stop.store(true, Ordering::Relaxed);
        trained
    })?;

    let seen = max_epoch_seen.load(Ordering::Relaxed);
    let ok = queries_ok.load(Ordering::Relaxed);
    let stats = server.shutdown();
    println!(
        "\nclients completed {ok} queries against live-swapped snapshots; \
         newest epoch observed mid-traffic: {seen}"
    );
    println!(
        "server: {} requests in {} batches (mean batch {:.1}), {} publishes",
        stats.served,
        stats.batches,
        stats.served as f64 / stats.batches.max(1) as f64,
        stats.swaps
    );
    anyhow::ensure!(ok > 0, "clients made no progress");
    anyhow::ensure!(seen >= 1, "hot-swapped snapshots never became visible");
    anyhow::ensure!(stats.swaps == 6);
    println!("server exited cleanly");
    Ok(())
}
