//! Train-and-serve concurrently: the tensor-completion service on the
//! session + serving subsystems.  A [`Server`] opens on the epoch-0
//! snapshot and keeps answering batched predict / top-K queries from
//! concurrent client threads while a scheduled [`Session`] run
//! (`publish_every: 1`) trains more epochs and hot-swaps fresh snapshots
//! in — in-flight queries always see one consistent model, and clients
//! observe the epoch tag advancing.
//!
//! Everything is in-process and offline (no sockets: a network front-end
//! would sit on top of the same [`ServerHandle`]).  CI runs this on every
//! PR.
//!
//! Run: `cargo run --release --example completion_server`
//!
//! [`ServerHandle`]: fasttucker::serve::ServerHandle

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use fasttucker::prelude::*;
use fasttucker::serve::Server;
use fasttucker::session::EpochEvent;
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::util::rng::Pcg32;

/// Narrates each hot-swap publish with the live query count — an
/// [`Observer`] over the session's epoch events.
struct PublishNarrator<'a> {
    server: &'a Server,
    queries_ok: &'a AtomicU64,
}

impl Observer for PublishNarrator<'_> {
    fn on_epoch(&mut self, ev: &EpochEvent) {
        if ev.published {
            println!(
                "epoch {}: published (server now at snapshot epoch {}, {} queries answered so far)",
                ev.epoch,
                self.server.epoch(),
                self.queries_ok.load(Ordering::Relaxed)
            );
        }
    }
}

fn main() -> anyhow::Result<()> {
    let tensor = generate(&SynthConfig::order_sweep(3, 256, 40_000, 5));
    let cfg = TrainConfig::default();
    let backend = cfg.auto_backend();
    if backend != Backend::Hlo {
        eprintln!("note: no artifacts; using --backend parallel");
    }
    // 6 epochs, publish after every one, no held-out split — the
    // completion service trains on every observed entry.
    let schedule = Schedule {
        epochs: 6,
        eval_every: 0,
        test_frac: 0.0,
        publish_every: 1,
        ..Schedule::default()
    };
    let dims = tensor.dims.clone();
    let cfg = TrainConfig { backend, ..cfg };
    let mut session = Session::with_owned_tensor(tensor, cfg, schedule)?;

    let server = Server::start(session.snapshot(), 2, 16);
    println!(
        "serving order-{} model over dims {:?} (snapshot epoch {})",
        dims.len(),
        dims,
        server.epoch()
    );

    // Client threads hammer the server while the main thread trains.
    let stop = AtomicBool::new(false);
    let max_epoch_seen = AtomicU64::new(0);
    let queries_ok = AtomicU64::new(0);
    std::thread::scope(|scope| -> anyhow::Result<()> {
        for c in 0..3u64 {
            let handle = server.handle();
            let stop = &stop;
            let max_epoch_seen = &max_epoch_seen;
            let queries_ok = &queries_ok;
            let dims = &dims;
            scope.spawn(move || {
                let mut rng = Pcg32::new(77, c);
                while !stop.load(Ordering::Relaxed) {
                    let coords: Vec<u32> = dims.iter().map(|&d| rng.gen_range(d)).collect();
                    let v = match c % 3 {
                        // mix predicts, top-K completions and epoch probes
                        0 => handle.predict(coords).expect("predict"),
                        1 => {
                            let top = handle.topk(coords, 2, 3).expect("topk");
                            top[0].score
                        }
                        _ => {
                            let e = handle.epoch().expect("epoch");
                            max_epoch_seen.fetch_max(e, Ordering::Relaxed);
                            e as f32
                        }
                    };
                    assert!(v.is_finite(), "query returned a non-finite value");
                    queries_ok.fetch_add(1, Ordering::Relaxed);
                }
            });
        }

        // The session publishes after every epoch — each one a hot-swap
        // under live traffic.  Always release the clients, even if the
        // run errors, so the scope can join.
        let mut narrator = PublishNarrator {
            server: &server,
            queries_ok: &queries_ok,
        };
        let trained = session.run_with_server(&server, &mut narrator).map(|_| ());
        stop.store(true, Ordering::Relaxed);
        trained
    })?;

    let seen = max_epoch_seen.load(Ordering::Relaxed);
    let ok = queries_ok.load(Ordering::Relaxed);
    let stats = server.shutdown();
    println!(
        "\nclients completed {ok} queries against live-swapped snapshots; \
         newest epoch observed mid-traffic: {seen}"
    );
    println!(
        "server: {} requests in {} batches (mean batch {:.1}), {} publishes",
        stats.served,
        stats.batches,
        stats.served as f64 / stats.batches.max(1) as f64,
        stats.swaps
    );
    anyhow::ensure!(ok > 0, "clients made no progress");
    anyhow::ensure!(seen >= 1, "hot-swapped snapshots never became visible");
    anyhow::ensure!(stats.swaps == 6);
    println!("server exited cleanly");
    Ok(())
}
