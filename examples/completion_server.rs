//! Tensor-completion service: train a model, then serve prediction queries
//! over a line-oriented TCP protocol (std-only; tokio is not in the offline
//! crate set).  Demonstrates the "decomposed once, queried forever" usage
//! the paper motivates for recommender backends.
//!
//! Protocol:  client sends `i1 i2 ... iN\n`, server replies `<prediction>\n`;
//! `quit` closes the connection.
//!
//! Run: `cargo run --release --example completion_server` (serves a few
//! self-issued queries, then exits — set `SERVE_FOREVER=1` to keep serving).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use fasttucker::coordinator::{Backend, Trainer, TrainConfig};
use fasttucker::model::TuckerModel;
use fasttucker::synth::{generate, SynthConfig};

fn serve(model: &TuckerModel, stream: TcpStream) -> anyhow::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 || line.trim() == "quit" {
            return Ok(());
        }
        let coords: Result<Vec<u32>, _> =
            line.split_whitespace().map(|t| t.parse::<u32>()).collect();
        let reply = match coords {
            Ok(c) if c.len() == model.order()
                && c.iter().zip(&model.dims).all(|(&i, &d)| i < d) =>
            {
                format!("{:.4}\n", model.predict_one(&c))
            }
            _ => "ERR expected N in-bounds indices\n".to_string(),
        };
        stream.write_all(reply.as_bytes())?;
    }
}

fn main() -> anyhow::Result<()> {
    // Train a small model first (or load one with --model).
    let args: Vec<String> = std::env::args().collect();
    let model = if let Some(pos) = args.iter().position(|a| a == "--model") {
        TuckerModel::load(std::path::Path::new(&args[pos + 1]))?
    } else {
        let tensor = generate(&SynthConfig::order_sweep(3, 256, 50_000, 5));
        let mut cfg = TrainConfig::default();
        if !cfg.hlo_available() {
            eprintln!("note: no artifacts; using --backend parallel");
            cfg.backend = Backend::ParallelCpu;
        }
        let mut trainer = Trainer::new(&tensor, cfg)?;
        for _ in 0..8 {
            trainer.epoch(&tensor)?;
        }
        trainer.model
    };

    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    println!("completion server on {addr} (order {}, dims {:?})", model.order(), model.dims);

    if std::env::var("SERVE_FOREVER").is_ok() {
        for stream in listener.incoming() {
            let model = model.clone();
            std::thread::spawn(move || {
                let _ = serve(&model, stream.expect("accept"));
            });
        }
        return Ok(());
    }

    // Self-test: issue a few queries from a client thread and print replies.
    let server_model = model.clone();
    let handle = std::thread::spawn(move || {
        let (stream, _) = listener.accept().expect("accept");
        serve(&server_model, stream).expect("serve");
    });
    let mut client = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(client.try_clone()?);
    for query in ["1 2 3", "10 20 30", "bad input", "9999 0 0", "quit"] {
        client.write_all(format!("{query}\n").as_bytes())?;
        if query == "quit" {
            break;
        }
        let mut reply = String::new();
        reader.read_line(&mut reply)?;
        println!("  {query:>12} -> {}", reply.trim());
    }
    handle.join().unwrap();
    println!("server exited cleanly");
    Ok(())
}
