//! Recommender-system scenario (the paper's motivating §1 workload):
//! decompose a user x item x time rating tensor, then answer completion
//! queries — "what would user u rate item i at time t?" — and produce
//! top-k recommendations per user from the learned factors.
//!
//! Run: `cargo run --release --example recommender`

use fasttucker::coordinator::{Backend, Trainer, TrainConfig};
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::tensor::split::train_test_split;

fn main() -> anyhow::Result<()> {
    // Small MovieLens-scale tensor: 2000 users x 800 items x 24 periods.
    let mut cfg_t = SynthConfig::netflix_like(120_000, 11);
    cfg_t.dims = vec![2000, 800, 24];
    let tensor = generate(&cfg_t);
    let (train, test) = train_test_split(&tensor, 0.2, 11);
    println!(
        "ratings: {} train / {} test over {:?}",
        train.nnz(),
        test.nnz(),
        tensor.dims
    );

    let mut cfg = TrainConfig::default();
    if !cfg.hlo_available() {
        eprintln!("note: no artifacts; using --backend parallel");
        cfg.backend = Backend::ParallelCpu;
    }
    let mut trainer = Trainer::new(&train, cfg)?;
    for epoch in 1..=12 {
        trainer.epoch(&train)?;
        if epoch % 4 == 0 {
            let (rmse, mae) = trainer.evaluate(&test)?;
            println!("epoch {epoch:>2}: test rmse {rmse:.4} mae {mae:.4}");
        }
    }

    // --- completion queries -------------------------------------------------
    let model = &trainer.model;
    println!("\nsample completions (user, item, t) -> predicted rating:");
    for e in (0..test.nnz()).step_by(test.nnz() / 5) {
        let c = test.coords(e);
        let pred = model.predict_one(c);
        println!(
            "  user {:>4} item {:>3} t {:>2}: predicted {:.2}, actual {:.2}",
            c[0], c[1], c[2], pred, test.values[e]
        );
    }

    // --- top-k recommendation -----------------------------------------------
    // Score every item for a user at the latest time slice; report top 5.
    let user = test.coords(0)[0];
    let t_latest = model.dims[2] - 1;
    let mut scored: Vec<(u32, f32)> = (0..model.dims[1])
        .map(|item| (item, model.predict_one(&[user, item, t_latest])))
        .collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("\ntop-5 items for user {user} at t={t_latest}:");
    for (item, score) in scored.iter().take(5) {
        println!("  item {item:>4}: score {score:.3}");
    }
    anyhow::ensure!(scored[0].1.is_finite());
    Ok(())
}
