//! Recommender-system scenario (the paper's motivating §1 workload) on the
//! session + serving subsystems: decompose a user x item x time rating
//! tensor through a scheduled [`Session`] run that publishes snapshots to
//! a live [`Server`] every few epochs, persist the trained model through
//! the full snapshot lifecycle (train → checkpoint → load → serve), and
//! answer the two production queries — point predictions ("what would
//! user u rate item i at time t?") and per-user top-K recommendation via
//! mode completion.
//!
//! Everything runs offline from a clean checkout (synthetic data, CPU
//! backend, temp-dir checkpoint).  CI runs this end-to-end on every PR.
//!
//! Run: `cargo run --release --example recommender`

use fasttucker::prelude::*;
use fasttucker::serve::{mode_topk, Engine, Server};
use fasttucker::synth::{generate, SynthConfig};

fn main() -> anyhow::Result<()> {
    // Small MovieLens-scale tensor: 2000 users x 800 items x 24 periods.
    let mut cfg_t = SynthConfig::netflix_like(90_000, 11);
    cfg_t.dims = vec![2000, 800, 24];
    let tensor = generate(&cfg_t);

    let cfg = TrainConfig::default();
    let backend = cfg.auto_backend();
    if backend != Backend::Hlo {
        eprintln!("note: no artifacts; using --backend parallel");
    }
    // The schedule drives everything the old hand-rolled loop did:
    // evaluate + publish every 3rd epoch, for 9 epochs.
    let schedule = Schedule {
        epochs: 9,
        eval_every: 3,
        test_frac: 0.2,
        publish_every: 3,
        ..Schedule::default()
    };
    let mut session = Session::with_tensor(&tensor, TrainConfig { backend, ..cfg }, schedule)?;
    println!(
        "ratings: {} train / {} test over {:?}",
        session.train_nnz(),
        session.test_tensor().nnz(),
        tensor.dims
    );

    // Serve while training: the server opens on the (untrained) epoch-0
    // snapshot and every publish hot-swaps in a better model.
    let server = Server::start(session.snapshot(), 2, 32);
    let report = session.run_with_server(&server, &mut ProgressPrinter)?;
    println!(
        "trained {} epochs; final test rmse {:.4} (published snapshot epoch {})",
        report.epochs_run,
        report.final_rmse.unwrap_or(f64::NAN),
        server.epoch()
    );

    // --- checkpoint lifecycle ----------------------------------------------
    // Persist the final model and serve from the durable copy — the
    // process-restart story.
    let dir = std::env::temp_dir().join("ft_recommender_example");
    std::fs::create_dir_all(&dir)?;
    let ckpt = dir.join("model.ftc");
    session.snapshot().save(&ckpt)?;
    let revived = ModelSnapshot::load(&ckpt)?;
    println!(
        "\ncheckpoint roundtrip: {:?} (epoch {}, {} params, checksum ok)",
        ckpt,
        revived.epoch(),
        revived.param_count()
    );
    anyhow::ensure!(revived.epoch() == session.trainer().epoch_no);
    server.publish(revived.clone());

    // --- completion queries (batched through the server) -------------------
    println!("\nsample completions (user, item, t) -> predicted rating:");
    let handle = server.handle();
    let test = session.test_tensor();
    for e in (0..test.nnz()).step_by(test.nnz() / 5) {
        let c = test.coords(e);
        let pred = handle.predict(c.to_vec()).map_err(anyhow::Error::msg)?;
        println!(
            "  user {:>4} item {:>3} t {:>2}: predicted {:.2}, actual {:.2}",
            c[0], c[1], c[2], pred, test.values[e]
        );
    }

    // --- top-K recommendation ----------------------------------------------
    // Score every item for a few users at the latest time slice (mode 1 is
    // the item mode); the fiber invariant over (user, t) is computed once
    // per user, not once per item.
    let t_latest = revived.dims()[2] - 1;
    println!("\ntop-5 items at t={t_latest}:");
    for e in (0..test.nnz()).step_by(test.nnz() / 3).take(3) {
        let user = test.coords(e)[0];
        let top = handle
            .topk(vec![user, 0, t_latest], 1, 5)
            .map_err(anyhow::Error::msg)?;
        let ranked: Vec<String> = top
            .iter()
            .map(|s| format!("{}:{:.3}", s.index, s.score))
            .collect();
        println!("  user {user:>4}: {}", ranked.join("  "));
    }

    // Cross-check the served ranking against a direct engine query on the
    // same snapshot — identical by construction.
    let probe_user = test.coords(0)[0];
    let served = handle
        .topk(vec![probe_user, 0, t_latest], 1, 5)
        .map_err(anyhow::Error::msg)?;
    let mut engine = Engine::new(revived);
    let direct = mode_topk(&mut engine, &[probe_user, 0, t_latest], 1, 5);
    anyhow::ensure!(served == direct, "served top-K diverged from direct engine query");
    anyhow::ensure!(served[0].score.is_finite());

    let stats = server.shutdown();
    println!(
        "\nserver: {} requests in {} batches, {} snapshot publishes",
        stats.served, stats.batches, stats.swaps
    );
    Ok(())
}
