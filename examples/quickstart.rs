//! Quickstart + end-to-end validation driver, on the session API.
//!
//! Describes the whole run declaratively — a Netflix-like synthetic
//! rating tensor (the laptop-scale surrogate for the paper's real
//! datasets, DESIGN.md §3), a FastTuckerPlus configuration with the
//! backend auto-selected for this checkout, and a fixed-epoch schedule
//! with per-epoch RMSE/MAE evaluation — then hands the [`RunSpec`] to a
//! [`Session`] and lets it drive.  The printed spec JSON is exactly what
//! `fasttucker train --dump-spec` emits, so this run is reproducible from
//! a file.  The numbers recorded in EXPERIMENTS.md §E2E come from this.
//!
//! Run: `cargo run --release --example quickstart`

use fasttucker::prelude::*;
use fasttucker::session::{DataSource, SynthPreset, SynthSpec};

fn main() -> anyhow::Result<()> {
    let nnz = std::env::var("QS_NNZ")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200_000);
    let epochs = std::env::var("QS_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(15);

    // QS_DATA points the run at a tensor file instead of the synthetic
    // surrogate — any supported format, including an ingested `.ftb2`
    // store (materialized here; `fasttucker train --store` keeps it
    // out of core).
    let data = match std::env::var("QS_DATA") {
        Ok(path) => DataSource::File(path.into()),
        Err(_) => DataSource::Synth(SynthSpec {
            preset: SynthPreset::Netflix,
            nnz,
            seed: 7,
            ..SynthSpec::default()
        }),
    };
    let spec = RunSpec {
        data,
        schedule: Schedule {
            epochs,
            ..Schedule::default()
        },
        ..RunSpec::default()
    };
    if spec.train.backend != Backend::Hlo {
        eprintln!("note: no artifacts (run `make artifacts` for the HLO backend); using --backend parallel");
    }
    println!("spec: {}", spec.dump());

    let mut session = Session::from_spec(&spec)?;
    println!(
        "dims {:?}, train {} / test {} entries",
        session.train_dims(),
        session.train_nnz(),
        session.test_tensor().nnz(),
    );
    println!("runtime: {}", session.platform());

    let report = session.run(&mut ProgressPrinter)?;

    let init = report
        .history
        .first()
        .and_then(|e| e.rmse)
        .expect("schedule evaluates the init");
    let best = report.best_rmse.expect("schedule evaluates epochs");
    println!(
        "done in {:.1}s; best test RMSE {best:.4} (init was {init:.4})",
        report.wall_s
    );
    anyhow::ensure!(best < 0.9 * init, "training failed to converge");
    println!("CONVERGED ✓");
    Ok(())
}
