//! Quickstart + end-to-end validation driver.
//!
//! Generates a Netflix-like synthetic rating tensor (the laptop-scale
//! surrogate for the paper's real datasets — DESIGN.md §3), trains a
//! FastTuckerPlus decomposition through the full three-layer stack
//! (Pallas-lowered HLO executed on the PJRT CPU client from the Rust
//! coordinator), and logs the RMSE/MAE convergence curve plus per-phase
//! timings.  The numbers recorded in EXPERIMENTS.md §E2E come from this.
//!
//! Run: `cargo run --release --example quickstart`

use fasttucker::coordinator::{Backend, Trainer, TrainConfig};
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::tensor::split::train_test_split;

fn main() -> anyhow::Result<()> {
    let nnz = std::env::var("QS_NNZ")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200_000);
    let epochs = std::env::var("QS_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(15);

    println!("generating netflix-like surrogate ({nnz} nnz)...");
    let tensor = generate(&SynthConfig::netflix_like(nnz, 7));
    let (train, test) = train_test_split(&tensor, 0.2, 7);
    println!(
        "dims {:?}, train {} / test {} entries, density {:.2e}",
        tensor.dims,
        train.nnz(),
        test.nnz(),
        tensor.density()
    );

    let mut cfg = TrainConfig::default(); // plus / tc / calculation
    if !cfg.hlo_available() {
        eprintln!("note: no artifacts (run `make artifacts` for the HLO backend); using --backend parallel");
        cfg.backend = Backend::ParallelCpu;
    }
    let mut trainer = Trainer::new(&train, cfg)?;
    println!("runtime: {}", trainer.platform());

    let (rmse, mae) = trainer.evaluate(&test)?;
    println!("epoch  0: rmse {rmse:.4} mae {mae:.4} (random init)");
    let t0 = std::time::Instant::now();
    let mut best = rmse;
    for epoch in 1..=epochs {
        let st = trainer.epoch(&train)?;
        let (rmse, mae) = trainer.evaluate(&test)?;
        best = best.min(rmse);
        println!(
            "epoch {epoch:>2}: rmse {rmse:.4} mae {mae:.4} | factor {:.3}s (exec {:.3}s, mem {:.3}s) core {:.3}s | pad {:.1}%",
            st.factor.total().as_secs_f64(),
            st.factor.exec.as_secs_f64(),
            st.factor.memory().as_secs_f64(),
            st.core.total().as_secs_f64(),
            100.0 * st.factor.padding_ratio()
        );
    }
    println!(
        "done in {:.1}s; best test RMSE {best:.4} (init was {rmse0:.4})",
        t0.elapsed().as_secs_f64(),
        rmse0 = rmse
    );
    anyhow::ensure!(best < 0.9 * rmse, "training failed to converge");
    println!("CONVERGED ✓");
    Ok(())
}
