//! HHLST scenario: high-order, high-dimensional, large-scale sparse
//! tensors — the regime the paper's Table 1 says only the FastTucker
//! family handles.  Sweeps tensor order 3..8 (the paper's §5.1 synthetic
//! family, laptop-scaled) and reports per-iteration time and the padding /
//! memory behaviour that drives the Fig. 2-3 curves.
//!
//! Run: `cargo run --release --example highorder`

use fasttucker::coordinator::{Algo, Backend, Trainer, TrainConfig};
use fasttucker::synth::{generate, SynthConfig};

fn main() -> anyhow::Result<()> {
    let backend = TrainConfig::default().auto_backend();
    if backend != Backend::Hlo {
        eprintln!("note: no artifacts; using --backend parallel");
    }
    println!(
        "{:<6} {:>10} {:>12} {:>12} {:>10} {:>8}",
        "order", "nnz", "factor", "core", "memory", "pad%"
    );
    for order in 3..=8 {
        let tensor = generate(&SynthConfig::order_sweep(order, 64, 30_000, 3));
        let mut cfg = TrainConfig::default();
        cfg.algo = Algo::Plus;
        cfg.backend = backend;
        let mut trainer = Trainer::new(&tensor, cfg)?;
        // warm the executables, then measure one epoch
        trainer.epoch(&tensor)?;
        let st = trainer.epoch(&tensor)?;
        println!(
            "{:<6} {:>10} {:>12} {:>12} {:>10} {:>7.1}%",
            order,
            tensor.nnz(),
            format!("{:.3}s", st.factor.total().as_secs_f64()),
            format!("{:.3}s", st.core.total().as_secs_f64()),
            format!(
                "{:.3}s",
                (st.factor.memory() + st.core.memory()).as_secs_f64()
            ),
            100.0 * st.factor.padding_ratio(),
        );
    }
    println!("\nFastTuckerPlus iteration time grows ~linearly with order");
    println!("(the paper's Fig. 2 shape) because D-chains share all C^(n).");
    Ok(())
}
